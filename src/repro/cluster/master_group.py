"""Sharded master group: split the master's Lagrange coding over d.

The master serializes all encode/decode work for the full model dimension
d each round; past a few thousand features that serial coding — not the
workers — caps round throughput.  The protocol math shards trivially: the
Lagrange encode (U^T applied along the K+T axis) and the streaming decode
fold are ELEMENTWISE-LINEAR across d, so a master group of size S can each
own a contiguous d-slice and run encode_dataset / the per-round weight
encode / the StreamingDecoder fold on 1/S of the columns, bit-identically
(DESIGN.md §13).

The ONE rule that keeps sharding bit-identical: ALL RANDOMNESS IS DRAWN AT
FULL SHAPE.  jax PRNG draws are shape-dependent — quantize_weights(kq,
w[shard]) is NOT quantize_weights(kq, w)[shard] — so the stochastic
quantization and the T privacy masks are generated once for the whole
model, and only the deterministic linear algebra (encode matmul, addmod,
decode folds) runs per shard.  Privacy is unchanged for the same reason:
the group holds exactly the masks a single master would hold.

Shard placement reuses the parallel/rules.py + launch/mesh.py machinery:
``make_local_mesh(model=S)`` + ``spec_for`` decide whether the model axis
genuinely shards d on this host's devices (divisible-or-replicate policy);
the group always runs S logical masters regardless — one single-thread
executor per master models S master processes, with per-master wall clocks.
On a box with >= S cores the numpy field arithmetic (which releases the
GIL) genuinely overlaps.  The per-master walls are PER-THREAD CPU seconds
(``time.thread_time`` on each master's own executor thread), so even on
fewer cores — where the threads timeslice and any wall clock would charge
each master for the others' turns — each wall still measures exactly that
master's 1/S share, and ``group_stats``'s critical path (max over masters)
estimates the group's deployment wall-clock, where the S masters are
separate processes on separate machines.
"""
from __future__ import annotations

import time as _time
from concurrent.futures import ThreadPoolExecutor
from typing import Any

import jax
import numpy as np

from repro.core import lagrange, quantize
from repro.core.protocol import decode, encode
from repro.core.protocol.config import CPMLConfig
from repro.launch.mesh import make_local_mesh
from repro.parallel import rules


def _host_encode_rows(u_rows: np.ndarray, stacked: np.ndarray, p: int
                      ) -> np.ndarray:
    """Exact mod-p U^T-apply on the host: (rows, N)^T @ (rows, M) -> (N, M).

    Reduced after every row so int64 never overflows (each product < p^2 <
    2^60, accumulator < 2^61) — the same discipline as the streaming
    decoder's fold, valid for both the 24-bit P and the 30-bit P30.
    """
    acc = np.zeros((u_rows.shape[1], stacked.shape[1]), np.int64)
    for k in range(u_rows.shape[0]):
        acc = (acc + u_rows[k][:, None] * stacked[k][None, :]) % p
    return acc


def d_shard_slices(cfg: CPMLConfig, d: int, size: int) -> list[slice]:
    """Contiguous d-slices for a master group of ``size``.

    Placement policy comes from the dormant sharding machinery: a local
    mesh with a model axis of (up to) ``size`` devices and the
    divisible-or-replicate rules decide whether d shards EVENLY over the
    model axis ('inner' is a model-sharded logical axis).  When it does,
    the slices are the exact equal blocks GSPMD would place; otherwise
    np.array_split's balanced blocks (sizes differ by at most one) keep
    every master's share within one column of 1/S.
    """
    size = max(1, min(int(size), d))
    mesh = make_local_mesh(model=size)
    spec = rules.spec_for(mesh, (d, cfg.c), ("inner", None))
    model_n = int(mesh.shape["model"])
    if spec and spec[0] == "model" and model_n == size and d % size == 0:
        step = d // size
        return [slice(i * step, (i + 1) * step) for i in range(size)]
    bounds = np.cumsum([0] + [len(a) for a in
                              np.array_split(np.arange(d), size)])
    return [slice(int(bounds[i]), int(bounds[i + 1])) for i in range(size)]


class ShardedStreamingDecoder:
    """S per-shard StreamingDecoders behind the one-decoder interface.

    Each shard's decoder runs on its master's single-thread executor, so
    same-shard folds keep arrival order while shards overlap each other
    (and the collect loop).  The DecodePlan is shared: its coefficient
    columns are (K,) per worker — d-independent — so every shard predicts
    and hits identically, and ``streamed`` agrees across shards.
    """

    def __init__(self, cfg: CPMLConfig, plan, slices: list[slice],
                 pools: list[ThreadPoolExecutor], walls: list[dict]):
        self.cfg = cfg
        self._slices = slices
        self._pools = pools
        self._walls = walls
        self._decs = [decode.StreamingDecoder(cfg, plan) for _ in slices]
        self._futs: list = []
        self.streamed = False

    def _timed(self, s: int, fn, *args):
        # thread_time: this master's own CPU seconds (see MasterGroup)
        t0 = _time.thread_time()
        try:
            return fn(*args)
        finally:
            self._walls[s]["decode_s"] += _time.thread_time() - t0

    def fold(self, worker: int, result) -> None:
        h = np.asarray(result, dtype=np.int32)
        self._futs = [
            pool.submit(self._timed, s, self._decs[s].fold, worker, h[sl])
            for s, (sl, pool) in enumerate(zip(self._slices, self._pools))]

    def finish(self, order: np.ndarray) -> np.ndarray:
        for f in self._futs:            # last fold must land before finish
            f.result()
        futs = [pool.submit(self._timed, s, self._decs[s].finish, order)
                for s, pool in enumerate(self._pools)]
        parts = [f.result() for f in futs]
        self.streamed = all(d.streamed for d in self._decs)
        return np.concatenate(parts, axis=1)        # (K, d, c) along d


class MasterGroup:
    """S logical masters, each owning a contiguous 1/S slice of d.

    Drop-in provider for the master-side coding surfaces the runner uses:
    ``encode_dataset`` (provision-time), ``encode_round_shares`` /
    ``encode_round_shares_split`` (per-round weight encode), and
    ``streaming_decoder`` (per-round decode).  Everything is bit-identical
    to the single-master jitted engine path (tests/test_master_group.py):
    randomness at full shape, linear algebra per shard, exact mod p.
    """

    def __init__(self, cfg: CPMLConfig, size: int = 1):
        assert size >= 1, f"master group size {size} < 1"
        self.cfg = cfg
        self.size = int(size)
        self._pools: list[ThreadPoolExecutor] = [
            ThreadPoolExecutor(max_workers=1,
                               thread_name_prefix=f"master{i}")
            for i in range(self.size)]
        # per-master wall-clock accounting (group_stats)
        self.walls: list[dict[str, float]] = [
            {"encode_s": 0.0, "decode_s": 0.0} for _ in range(self.size)]
        self._u = np.asarray(cfg.scheme.encode_matrix, np.int64)  # (K+T, N)

    # -- plumbing -------------------------------------------------------

    def close(self) -> None:
        for p in self._pools:
            p.shutdown(wait=True)

    def __enter__(self) -> "MasterGroup":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _encode_sharded(self, stacked: np.ndarray, axis: int,
                        mask_shares: np.ndarray | None = None) -> np.ndarray:
        """Apply U^T (all rows, or just the K data rows + given encoded mask
        contribution) per d-shard; ``axis`` is stacked's d axis (excluding
        the leading rows axis handled by the matmul)."""
        cfg = self.cfg
        d = stacked.shape[axis]
        slices = d_shard_slices(cfg, d, self.size)
        u_rows = self._u if mask_shares is None else self._u[: cfg.K]

        def one(s: int, sl: slice) -> np.ndarray:
            t0 = _time.thread_time()
            try:
                sub = np.take(stacked, np.arange(sl.start, sl.stop),
                              axis=axis)
                flat = sub.reshape(sub.shape[0], -1).astype(np.int64)
                out = _host_encode_rows(u_rows, flat, cfg.p)  # (N, M)
                out = out.reshape(cfg.N, *sub.shape[1:])
                if mask_shares is not None:
                    msub = np.take(mask_shares,
                                   np.arange(sl.start, sl.stop), axis=axis)
                    out = (out + msub.astype(np.int64)) % cfg.p
                return out.astype(np.int32)
            finally:
                self.walls[s]["encode_s"] += _time.thread_time() - t0

        futs = [pool.submit(one, s, sl)
                for s, (sl, pool) in enumerate(zip(slices,
                                                   self._pools[: len(slices)]))]
        return np.concatenate([f.result() for f in futs], axis=axis)

    # -- provision-time dataset encode ----------------------------------

    def encode_dataset(self, cfg: CPMLConfig, key: jax.Array, x: jax.Array
                       ) -> tuple[np.ndarray, dict[str, Any]]:
        """Sharded twin of encode.encode_dataset (same signature, so it
        plugs into engine.setup's ``dataset_encoder`` hook).  Quantization
        and the T masks are full-shape; only the (K+T)-row encode matmul
        runs per d-shard."""
        xq = quantize.quantize_data(x, cfg.lx, cfg.p)
        xq = encode.pad_rows(xq, cfg.K)
        mk = xq.shape[0] // cfg.K
        parts = np.asarray(xq.reshape(cfg.K, mk, xq.shape[-1]))
        masks = np.asarray(
            lagrange.draw_masks(key, cfg.T, parts.shape[1:], cfg.p))
        stacked = (np.concatenate([parts, masks], axis=0) if cfg.T
                   else parts)                       # (K+T, mk, d)
        shares = self._encode_sharded(stacked, axis=2)
        return shares, {"xq": xq, "m_padded": int(xq.shape[0])}

    # -- per-round weight encode ----------------------------------------

    def encode_round_shares(self, key: jax.Array, w2) -> np.ndarray:
        """Sharded twin of engine.encode_round_shares: same key split, same
        full-shape quantize + masks, per-shard encode.  (N, d, c, r)."""
        cfg = self.cfg
        kq, km = jax.random.split(key)
        wbar = np.asarray(
            quantize.quantize_weights(kq, w2, cfg.lw, cfg.r, cfg.p))
        masks = np.asarray(
            lagrange.draw_masks(km, cfg.T, wbar.shape, cfg.p))
        parts = np.broadcast_to(wbar[None], (cfg.K, *wbar.shape))
        stacked = (np.concatenate([parts, masks], axis=0) if cfg.T
                   else np.ascontiguousarray(parts))  # (K+T, d, c, r)
        return self._encode_sharded(stacked, axis=1)

    def encode_round_shares_split(self, kq: jax.Array, mask_shares,
                                  w2) -> np.ndarray:
        """Sharded twin of engine.encode_round_shares_split: the
        W-dependent finish only — quantize at full shape, then per shard
        the K-row data encode plus the prefetched mask contribution."""
        cfg = self.cfg
        wbar = np.asarray(
            quantize.quantize_weights(kq, w2, cfg.lw, cfg.r, cfg.p))
        parts = np.ascontiguousarray(
            np.broadcast_to(wbar[None], (cfg.K, *wbar.shape)))
        return self._encode_sharded(parts, axis=1,
                                    mask_shares=np.asarray(mask_shares))

    # -- per-round decode ------------------------------------------------

    def make_decoder(self, plan, d: int) -> ShardedStreamingDecoder:
        """A sharded streaming decoder over this group's executors."""
        slices = d_shard_slices(self.cfg, d, self.size)
        return ShardedStreamingDecoder(self.cfg, plan, slices,
                                       self._pools[: len(slices)],
                                       self.walls)

    # -- accounting ------------------------------------------------------

    def group_stats(self) -> dict[str, Any]:
        """Per-master encode/decode walls + the group critical path.

        ``critical_path_s`` is the max over masters of (encode + decode)
        per-thread CPU wall — the group's deployment wall-clock, where the
        S masters run as separate processes.  Matches the measured wall
        when this host has >= S cores (numpy field ops release the GIL);
        on fewer cores it is the honest estimate a wall clock cannot give."""
        per = [dict(w) for w in self.walls]
        return {
            "size": self.size,
            "per_master": per,
            "encode_total_s": float(sum(w["encode_s"] for w in per)),
            "decode_total_s": float(sum(w["decode_s"] for w in per)),
            "critical_path_s": float(max(
                w["encode_s"] + w["decode_s"] for w in per)),
        }
