"""Transport abstraction for the coded cluster runtime (DESIGN.md §7).

A transport moves typed messages (messages.py) between named endpoints
("master", "worker/3").  The interface is PURE asynchronous message passing
— send with a delay, receive what has arrived, peek at the next arrival
time — so the same master/scheduler code runs unchanged over the socket
backend (socket_transport.py), where "delay" is real network+compute time
and ``next_delivery`` is a bounded blocking poll.  The backend-shared
contract suite (tests/test_transport_contract.py) pins both to the same
semantics.

``InProcessTransport`` is the simulation backend: a per-endpoint heap of
(deliver_at, seq, msg).  It owns no clock; the EventScheduler advances
simulated time TO the transport's next delivery — the transport is the
event queue.
"""
from __future__ import annotations

import abc
import heapq
import itertools
import math
from typing import Any, Iterable


class Transport(abc.ABC):
    """Typed-message channel between named endpoints.

    ``real`` distinguishes the two time regimes the contract supports:
    simulated backends deliver on an externally-advanced clock (the
    scheduler moves time TO ``next_delivery``), while real backends
    (cluster/socket_transport.py) stamp arrivals with the wall clock and
    ``next_delivery`` is a bounded blocking poll — None means "nothing yet",
    not "nothing ever".
    """

    real: bool = False

    @abc.abstractmethod
    def send(self, dst: str, msg: Any, at: float, delay: float = 0.0
             ) -> None:
        """Schedule ``msg`` for delivery to ``dst`` at time ``at + delay``.

        ``delay == math.inf`` is legal and means the message is lost (dead
        worker): it never becomes visible to ``recv``/``next_delivery``.
        """

    @abc.abstractmethod
    def recv(self, dst: str, now: float) -> list[tuple[float, Any]]:
        """Pop every (deliver_time, msg) for ``dst`` due by ``now``,
        in delivery order."""

    @abc.abstractmethod
    def next_delivery(self, dst: str) -> float | None:
        """Earliest pending delivery time for ``dst`` (None = queue empty)."""


class InProcessTransport(Transport):
    def __init__(self):
        self._queues: dict[str, list[tuple[float, int, Any]]] = {}
        self._seq = itertools.count()   # FIFO tiebreak for equal times

    def send(self, dst: str, msg: Any, at: float, delay: float = 0.0) -> None:
        deliver_at = at + delay
        if math.isinf(deliver_at):
            return                      # lost in the void: dead worker
        heapq.heappush(self._queues.setdefault(dst, []),
                       (deliver_at, next(self._seq), msg))

    def recv(self, dst: str, now: float) -> list[tuple[float, Any]]:
        q = self._queues.get(dst, [])
        out = []
        while q and q[0][0] <= now:
            t, _, msg = heapq.heappop(q)
            out.append((t, msg))
        return out

    def next_delivery(self, dst: str) -> float | None:
        q = self._queues.get(dst)
        return q[0][0] if q else None

    # simulation-only introspection (not part of the Transport contract):
    def pending(self, dst: str) -> Iterable[tuple[float, Any]]:
        """(deliver_at, msg) for every undelivered message, unordered."""
        return [(t, msg) for t, _, msg in self._queues.get(dst, [])]
