"""Wire format for the socket transport: length-prefixed, pickle-free frames.

Every frame is ``u32 body length (big-endian) || body``; the body is a
one-byte frame tag followed by a self-describing, recursively tagged value
encoding.  Three design constraints (DESIGN.md §7):

  * NO PICKLE — the master deserializes bytes from worker processes; the
    decoder only ever constructs ints/floats/strs/arrays/containers and the
    three message dataclasses, never arbitrary objects.
  * EXACT — field arrays travel as dtype/shape header + raw little-endian
    bytes (bit-faithful int32 in [0, p), both the 24-bit P and 30-bit P30);
    python-int payloads (e.g. exact decode-matrix entries from the host
    Lagrange solve) are encoded as sign + big-endian magnitude at arbitrary
    precision, so nothing is silently truncated to 64 bits.
  * FAIL LOUD — malformed or truncated input raises ``WireError`` with a
    description of what broke; it never hangs and never returns garbage.

``serialize``/``deserialize`` round-trip the message dataclasses
(messages.py) plus two socket-layer frames: HELLO (endpoint registration on
connect) and RAW (an arbitrary encodable value — used by the backend-shared
transport contract tests, which ship plain strings/ints).

Two wire VERSIONS coexist (DESIGN.md §10).  v1 is the original encoding
above.  v2 adds three encodings that cut bytes and copies without touching
the value semantics — every v2 frame decodes to a message ``messages_equal``
to its v1 twin:

  * PACKED (value tag): a non-negative int32 array whose max fits in
    1/2/3 bytes ships that many little-endian bytes per element instead of
    4.  Field shares under the 24-bit prime P pack to 3 bytes/element;
    P30 shares exceed 24 bits and fall back to the raw 4-byte encoding.
    This is LOSSLESS dtype narrowing keyed on the actual value range
    (core/quantize.py's ``wire_itemsize`` gives the per-prime width), never
    lossy compression — coded shares must stay bit-exact.
  * ROUND (frame tag): the per-(worker, round) EncodeShare whose payload is
    the scheduler's ``{"w_share", "batch", "next_batch"}`` dict coalesces
    into ONE compact frame (presence bitmap + packed arrays) instead of a
    generic dict encoding.
  * HELLO2 (frame tag): HELLO plus the sender's wire version, the
    negotiation handshake.  A v1 peer sends plain HELLO and is spoken to in
    v1 forever; a v2 master acks HELLO2 so both sides upgrade.
  * TRACED RESULT (frame tags): a WorkerResult/CombineResult whose optional
    ``trace`` field (worker-side observability spans, DESIGN.md §11) is
    non-None ships it appended to the classic field layout.  Serializing at
    v1 silently DROPS the trace and emits the classic frame — a v1 fleet
    round-trips with worker traces simply absent, never with an error.

Encoders take an explicit ``version`` and NEVER emit v2 tags below
``WIRE_V2``; decoders take the version negotiated for the stream and reject
v2 tags on a v1 stream exactly as a real v1 peer would (unknown tag).

``serialize_iovec`` is the zero-copy path: it returns the frame as a list
of buffers (header runs as small ``bytes``, array bodies as ``memoryview``s
of the arrays themselves) ready for ``socket.sendmsg`` scatter-gather — the
hot path never materializes a joined frame copy.  ``serialize`` is the
``b"".join`` of it, kept for tests and one-shot callers.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any

import numpy as np

from repro.cluster.messages import (
    ROUND_PAYLOAD_KEYS,
    CombineResult,
    EncodeShare,
    Epoch,
    Heartbeat,
    Join,
    Prediction,
    Query,
    SubShare,
    WorkerResult,
)

MAX_FRAME_BYTES = 1 << 30        # reject absurd length prefixes outright

WIRE_V1 = 1                      # original tagged encoding
WIRE_V2 = 2                      # + PACKED / ROUND / HELLO2
WIRE_VERSION = WIRE_V2           # newest version this build speaks

# frame tags (first body byte)
_FRAME_ENCODE_SHARE = 0x10
_FRAME_WORKER_RESULT = 0x11
_FRAME_HEARTBEAT = 0x12
_FRAME_HELLO = 0x13
_FRAME_RAW = 0x14
_FRAME_FORWARD = 0x15
_FRAME_SUB_SHARE = 0x16
_FRAME_COMBINE_RESULT = 0x17
_FRAME_HELLO2 = 0x18             # v2: HELLO + sender wire version
_FRAME_ROUND = 0x19              # v2: coalesced (worker, round) EncodeShare
_FRAME_WORKER_RESULT_T = 0x1A    # v2: WorkerResult + piggy-backed TRACE
_FRAME_COMBINE_RESULT_T = 0x1B   # v2: CombineResult + piggy-backed TRACE
_FRAME_QUERY = 0x1C              # serving plane: client -> master request
_FRAME_PREDICTION = 0x1D         # serving plane: master -> client answer
_FRAME_JOIN = 0x1E               # v2: elastic membership join request
_FRAME_EPOCH = 0x1F              # v2: membership epoch fan-out
_FRAME_FROUND = 0x20             # v2: ALCC float round share (raw f32 blob)
_FRAME_FRESULT = 0x21            # v2: ALCC float worker result [+ TRACE]

# value tags
_T_NONE = 0x00
_T_FALSE = 0x01
_T_TRUE = 0x02
_T_INT = 0x03
_T_FLOAT = 0x04
_T_STR = 0x05
_T_BYTES = 0x06
_T_NDARRAY = 0x07
_T_INTARRAY = 0x08               # object-dtype array of exact python ints
_T_LIST = 0x09
_T_TUPLE = 0x0A
_T_DICT = 0x0B
_T_PACKED = 0x0C                 # v2: bit-packed non-negative int32 array

# array bodies at least this big ride as memoryviews in the iovec; smaller
# ones are folded into the adjacent header bytes (fewer sendmsg buffers)
_BLOB_MIN = 256


class WireError(ValueError):
    """Malformed, truncated, or unencodable wire data."""


@dataclasses.dataclass(frozen=True)
class Hello:
    """Connection registration: the first frame a client sends names its
    endpoint ("worker/3") so the master can route by destination.

    ``version`` is the sender's wire version.  On the wire a v1 HELLO has no
    version field (decodes as 1); a v2 sender uses the HELLO2 frame, and the
    master acks with its own HELLO2 so both directions upgrade (DESIGN.md
    §10).  Both ends speak ``min(theirs, ours)`` per peer thereafter.
    """
    endpoint: str
    version: int = WIRE_V1


@dataclasses.dataclass(frozen=True)
class Raw:
    """An arbitrary encodable value as a message (transport contract tests
    exercise the backends with plain strings/ints, not protocol messages)."""
    value: Any


@dataclasses.dataclass(frozen=True)
class Forward:
    """Socket-layer relay envelope: deliver ``frame`` (one complete
    serialized frame) to endpoint ``dst``.

    The socket topology is a star — workers hold one connection, to the
    master — so worker->worker traffic (SubShare, DESIGN.md §7) rides to the
    master wrapped in a Forward, and the master writes the inner frame bytes
    to the destination connection VERBATIM (no re-serialization on the relay
    hop).  The inner frame is always encoded at v1: the sender cannot know
    what version the final recipient negotiated.  Never surfaced to recv():
    the transport consumes it.
    """
    dst: str
    frame: bytes


# ---------------------------------------------------------------------------
# Value encoding
# ---------------------------------------------------------------------------

def _enc_u32(n: int) -> bytes:
    return struct.pack(">I", n)


def _pack_itemsize(vmax: int) -> int:
    """Bytes/element for the PACKED encoding of values in [0, vmax]."""
    return 1 if vmax < (1 << 8) else 2 if vmax < (1 << 16) else 3


def _append_blob(out: list, arr: np.ndarray) -> None:
    """Array body -> iovec entry: a memoryview of the array itself when big
    enough to be worth a scatter-gather slot, a small bytes copy otherwise
    (0-d and tiny arrays aren't worth an iovec entry)."""
    if arr.nbytes >= _BLOB_MIN and arr.ndim > 0:
        out.append(memoryview(arr).cast("B"))
    else:
        out.append(arr.tobytes())


def _enc_value(v: Any, out: list, version: int = WIRE_V1) -> None:
    if v is None:
        out.append(bytes([_T_NONE]))
    elif isinstance(v, bool):
        out.append(bytes([_T_TRUE if v else _T_FALSE]))
    elif isinstance(v, (int, np.integer)):
        v = int(v)
        mag = abs(v)
        body = mag.to_bytes((mag.bit_length() + 7) // 8, "big")
        out.append(bytes([_T_INT, 1 if v < 0 else 0]) + _enc_u32(len(body))
                   + body)
    elif isinstance(v, (float, np.floating)):
        out.append(bytes([_T_FLOAT]) + struct.pack(">d", float(v)))
    elif isinstance(v, str):
        b = v.encode("utf-8")
        out.append(bytes([_T_STR]) + _enc_u32(len(b)) + b)
    elif isinstance(v, bytes):
        out.append(bytes([_T_BYTES]) + _enc_u32(len(v)) + v)
    elif isinstance(v, np.ndarray) and v.dtype == object:
        # exact python-int matrices (host Lagrange solves): element-wise
        # arbitrary-precision ints, never truncated to a machine word.
        out.append(bytes([_T_INTARRAY, v.ndim]))
        for dim in v.shape:
            out.append(_enc_u32(dim))
        for e in v.reshape(-1):
            if not isinstance(e, (int, np.integer)):
                raise WireError(
                    f"object arrays may only hold ints, got {type(e).__name__}")
            _enc_value(int(e), out)
    elif isinstance(v, np.ndarray):
        if version >= WIRE_V2 and v.dtype == np.int32 and v.size:
            a = np.ascontiguousarray(v, dtype="<i4")
            vmin, vmax = int(a.min()), int(a.max())
            if vmin >= 0 and vmax < (1 << 24):
                # lossless narrowing: the low `w` little-endian bytes of
                # each element carry the full value (field shares under the
                # 24-bit P: w=3; P30 shares miss this branch and ship raw)
                w = _pack_itemsize(vmax)
                out.append(bytes([_T_PACKED, w, v.ndim])
                           + b"".join(_enc_u32(d) for d in v.shape))
                flat = a.reshape(-1).view(np.uint8).reshape(-1, 4)[:, :w]
                _append_blob(out, np.ascontiguousarray(flat))
                return
        dt = v.dtype.newbyteorder("<")
        ds = dt.str.encode("ascii")
        out.append(bytes([_T_NDARRAY, len(ds)]) + ds + bytes([v.ndim]))
        for dim in v.shape:
            out.append(_enc_u32(dim))
        _append_blob(out, np.ascontiguousarray(v, dtype=dt))
    elif isinstance(v, list):
        out.append(bytes([_T_LIST]) + _enc_u32(len(v)))
        for e in v:
            _enc_value(e, out, version)
    elif isinstance(v, tuple):
        out.append(bytes([_T_TUPLE]) + _enc_u32(len(v)))
        for e in v:
            _enc_value(e, out, version)
    elif isinstance(v, dict):
        out.append(bytes([_T_DICT]) + _enc_u32(len(v)))
        for k, e in v.items():
            if not isinstance(k, str):
                raise WireError(f"dict keys must be str, got {type(k).__name__}")
            _enc_value(k, out, version)
            _enc_value(e, out, version)
    else:
        # device arrays (jax) quack like arrays; anything else is a bug.
        arr = np.asarray(v)
        if arr.dtype == object:
            raise WireError(f"cannot encode {type(v).__name__}")
        _enc_value(arr, out, version)


class _Reader:
    """Cursor over one frame body.  Works on a memoryview so buffered and
    zero-copy callers share one parser; ``version`` is the stream's
    negotiated wire version — v2 tags on a v1 stream are rejected exactly
    like any unknown tag, which is what a REAL v1 peer would do."""

    def __init__(self, data, version: int = WIRE_VERSION):
        self.data = data if isinstance(data, memoryview) else memoryview(data)
        self.pos = 0
        self.version = version

    def take(self, n: int) -> memoryview:
        if n < 0 or self.pos + n > len(self.data):
            raise WireError(
                f"truncated frame: wanted {n} bytes at offset {self.pos}, "
                f"frame has {len(self.data)}")
        b = self.data[self.pos: self.pos + n]
        self.pos += n
        return b

    def u8(self) -> int:
        return self.take(1)[0]

    def u32(self) -> int:
        return struct.unpack(">I", self.take(4))[0]


def _dec_value(r: _Reader) -> Any:
    tag = r.u8()
    if tag == _T_NONE:
        return None
    if tag == _T_FALSE:
        return False
    if tag == _T_TRUE:
        return True
    if tag == _T_INT:
        neg = r.u8()
        mag = int.from_bytes(r.take(r.u32()), "big")
        return -mag if neg else mag
    if tag == _T_FLOAT:
        return struct.unpack(">d", r.take(8))[0]
    if tag == _T_STR:
        return bytes(r.take(r.u32())).decode("utf-8")
    if tag == _T_BYTES:
        return bytes(r.take(r.u32()))
    if tag == _T_NDARRAY:
        # the fail-loud contract covers garbage INSIDE fields too: a bogus
        # dtype string or impossible shape must surface as WireError, not
        # as whatever numpy happens to raise
        try:
            dt = np.dtype(bytes(r.take(r.u8())).decode("ascii"))
        except Exception as e:
            raise WireError(f"malformed ndarray dtype: {e}") from None
        shape = tuple(r.u32() for _ in range(r.u8()))
        n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        try:
            arr = np.frombuffer(r.take(n), dtype=dt).reshape(shape)
        except WireError:
            raise
        except Exception as e:
            raise WireError(f"malformed ndarray body: {e}") from None
        return arr.copy()             # writable, detached from the buffer
    if tag == _T_PACKED:
        if r.version < WIRE_V2:
            raise WireError(f"unknown value tag 0x{tag:02x} "
                            f"(wire v2 PACKED on a v1 stream)")
        w = r.u8()
        if not 1 <= w <= 3:
            raise WireError(f"packed array itemsize {w} not in 1..3")
        shape = tuple(r.u32() for _ in range(r.u8()))
        n = int(np.prod(shape, dtype=np.int64))
        raw = r.take(n * w)
        # reassemble directly into the preallocated 4-byte-strided array:
        # low `w` bytes from the wire, high bytes already zero
        quad = np.zeros((n, 4), dtype=np.uint8)
        if n:
            quad[:, :w] = np.frombuffer(raw, dtype=np.uint8).reshape(n, w)
        return quad.view("<i4").reshape(shape)
    if tag == _T_INTARRAY:
        shape = tuple(r.u32() for _ in range(r.u8()))
        n = int(np.prod(shape, dtype=np.int64))
        arr = np.empty(n, dtype=object)
        for i in range(n):
            arr[i] = _dec_value(r)
        return arr.reshape(shape)
    if tag == _T_LIST:
        return [_dec_value(r) for _ in range(r.u32())]
    if tag == _T_TUPLE:
        return tuple(_dec_value(r) for _ in range(r.u32()))
    if tag == _T_DICT:
        return {_dec_value(r): _dec_value(r) for _ in range(r.u32())}
    raise WireError(f"unknown value tag 0x{tag:02x}")


# ---------------------------------------------------------------------------
# Message frames
# ---------------------------------------------------------------------------

def _round_frame_eligible(msg: EncodeShare) -> bool:
    """Exactly the scheduler's round-dispatch payload shape (runner.py):
    all three ROUND_PAYLOAD_KEYS present, each an array or None."""
    p = msg.payload
    return (isinstance(p, dict) and set(p) == set(ROUND_PAYLOAD_KEYS)
            and all(p[k] is None or isinstance(p[k], np.ndarray)
                    for k in ROUND_PAYLOAD_KEYS))


def _is_f32(v) -> bool:
    return isinstance(v, np.ndarray) and v.dtype == np.float32


def _enc_f32nd(v: np.ndarray, out: list) -> None:
    """float32 ndarray body for the ALCC frames: ndim, dims, raw
    little-endian blob — no per-value tag, the frame layout implies it."""
    a = np.ascontiguousarray(v, dtype="<f4")
    out.append(bytes([a.ndim]) + b"".join(_enc_u32(d) for d in a.shape))
    _append_blob(out, a)


def _dec_f32nd(r: _Reader) -> np.ndarray:
    shape = tuple(r.u32() for _ in range(r.u8()))
    n = int(np.prod(shape, dtype=np.int64)) * 4
    try:
        arr = np.frombuffer(r.take(n), dtype="<f4").reshape(shape)
    except WireError:
        raise
    except Exception as e:
        raise WireError(f"malformed float32 body: {e}") from None
    return arr.copy()


def serialize_iovec(msg: Any, version: int = WIRE_V1) -> list:
    """Message -> one frame as a buffer list for ``socket.sendmsg``.

    Header/scalar runs are small ``bytes``; array bodies are ``memoryview``s
    over the (contiguous, possibly packed) arrays themselves — the caller
    hands the list straight to sendmsg without ever joining it.  Entry 0
    starts with the u32 length prefix.
    """
    out: list = []
    if isinstance(msg, EncodeShare):
        if _round_frame_eligible(msg) and _is_f32(msg.payload["w_share"]):
            # ALCC float round share: like Join/Epoch, a v2-only protocol
            # feature — a v1 peer has no float frame to downgrade to, and
            # silently riding the generic dict path would hide that the
            # fleet is mixed, so fail loud at the serializer
            if version < WIRE_V2:
                raise WireError(
                    "float (ALCC) round shares are a wire v2 frame; the "
                    "whole fleet must negotiate wire v2")
            out.append(bytes([_FRAME_FROUND]))
            _enc_value(msg.round, out)
            _enc_value(msg.worker, out)
            present = 0
            for i, k in enumerate(ROUND_PAYLOAD_KEYS):
                if msg.payload[k] is not None:
                    present |= 1 << i
            out.append(bytes([present]))
            for k in ROUND_PAYLOAD_KEYS:
                v = msg.payload[k]
                if v is None:
                    continue
                if _is_f32(v):
                    out.append(b"\x01")
                    _enc_f32nd(v, out)
                else:                  # batch indices stay int32 / PACKED
                    out.append(b"\x00")
                    _enc_value(v, out, version)
        elif version >= WIRE_V2 and _round_frame_eligible(msg):
            out.append(bytes([_FRAME_ROUND]))
            _enc_value(msg.round, out)
            _enc_value(msg.worker, out)
            present = 0
            for i, k in enumerate(ROUND_PAYLOAD_KEYS):
                if msg.payload[k] is not None:
                    present |= 1 << i
            out.append(bytes([present]))
            for k in ROUND_PAYLOAD_KEYS:
                if msg.payload[k] is not None:
                    _enc_value(msg.payload[k], out, version)
        else:
            out.append(bytes([_FRAME_ENCODE_SHARE]))
            _enc_value(msg.round, out)
            _enc_value(msg.worker, out)
            _enc_value(msg.payload, out, version)
    elif isinstance(msg, WorkerResult):
        if _is_f32(msg.payload):
            # ALCC float result: v2-only, mirroring the FROUND refusal
            if version < WIRE_V2:
                raise WireError(
                    "float (ALCC) worker results are a wire v2 frame; the "
                    "whole fleet must negotiate wire v2")
            traced = msg.trace is not None
            out.append(bytes([_FRAME_FRESULT, 1 if traced else 0]))
            _enc_value(msg.round, out)
            _enc_value(msg.worker, out)
            _enc_value(msg.compute_s, out)
            _enc_f32nd(msg.payload, out)
            if traced:
                _enc_value(msg.trace, out, version)
        else:
            # TRACE rides a v2-only frame; at v1 the field is dropped and
            # the receiver sees a classic result — the same "older peers
            # simply never see the new field" negotiation shape as HELLO2
            traced = version >= WIRE_V2 and msg.trace is not None
            out.append(bytes([_FRAME_WORKER_RESULT_T if traced
                              else _FRAME_WORKER_RESULT]))
            _enc_value(msg.round, out)
            _enc_value(msg.worker, out)
            _enc_value(msg.compute_s, out)
            _enc_value(msg.payload, out, version)
            if traced:
                _enc_value(msg.trace, out, version)
    elif isinstance(msg, SubShare):
        out.append(bytes([_FRAME_SUB_SHARE]))
        _enc_value(msg.round, out)
        _enc_value(msg.phase, out)
        _enc_value(msg.src, out)
        _enc_value(msg.dst, out)
        _enc_value(msg.payload, out, version)
    elif isinstance(msg, CombineResult):
        traced = version >= WIRE_V2 and msg.trace is not None
        out.append(bytes([_FRAME_COMBINE_RESULT_T if traced
                          else _FRAME_COMBINE_RESULT]))
        _enc_value(msg.round, out)
        _enc_value(msg.worker, out)
        _enc_value(msg.compute_s, out)
        _enc_value(msg.payload, out, version)
        if traced:
            _enc_value(msg.trace, out, version)
    elif isinstance(msg, Query):
        # version-agnostic like SubShare: the frame layout never changes,
        # only the payload's value encoding upgrades (PACKED under v2)
        out.append(bytes([_FRAME_QUERY]))
        _enc_value(msg.qid, out)
        _enc_value(msg.client, out)
        _enc_value(msg.sent_at, out)
        _enc_value(msg.x, out, version)
    elif isinstance(msg, Prediction):
        out.append(bytes([_FRAME_PREDICTION]))
        _enc_value(msg.qid, out)
        _enc_value(msg.client, out)
        _enc_value(msg.y, out, version)
        _enc_value(msg.latency_s, out)
    elif isinstance(msg, Join):
        # elastic membership is a v2 protocol: a v1 fleet has no JOIN frame
        # (fixed-fleet semantics stay bit-identical), so serializing one at
        # v1 is a caller bug — fail loud instead of inventing a downgrade
        if version < WIRE_V2:
            raise WireError("Join is a wire v2 frame; a v1 fleet has no "
                            "elastic membership")
        out.append(bytes([_FRAME_JOIN]))
        _enc_value(msg.worker, out)
        _enc_value(msg.at_round, out)
        _enc_value(msg.sent_at, out)
    elif isinstance(msg, Epoch):
        if version < WIRE_V2:
            raise WireError("Epoch is a wire v2 frame; the master must skip "
                            "v1 peers when broadcasting membership epochs")
        out.append(bytes([_FRAME_EPOCH]))
        _enc_value(msg.epoch, out)
        _enc_value(None if msg.members is None
                   else tuple(int(w) for w in msg.members), out)
        _enc_value(msg.round, out)
    elif isinstance(msg, Heartbeat):
        out.append(bytes([_FRAME_HEARTBEAT]))
        _enc_value(msg.worker, out)
        _enc_value(msg.sent_at, out)
    elif isinstance(msg, Forward):
        out.append(bytes([_FRAME_FORWARD]))
        _enc_value(msg.dst, out)
        _enc_value(msg.frame, out)
    elif isinstance(msg, Hello):
        if version >= WIRE_V2 and msg.version >= WIRE_V2:
            out.append(bytes([_FRAME_HELLO2]))
            _enc_value(msg.endpoint, out)
            _enc_value(msg.version, out)
        else:
            # a v1 wire cannot express a version: the field is dropped and
            # the receiver correctly infers a v1 peer
            out.append(bytes([_FRAME_HELLO]))
            _enc_value(msg.endpoint, out)
    else:
        out.append(bytes([_FRAME_RAW]))
        _enc_value(msg, out, version)
    body_len = sum(len(c) for c in out)
    if body_len > MAX_FRAME_BYTES:
        raise WireError(f"frame body of {body_len} bytes exceeds "
                        f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    return _coalesce_iovec([_enc_u32(body_len)] + out)


def _coalesce_iovec(parts: list) -> list:
    """Merge adjacent small chunks into single buffers so the iovec stays a
    handful of entries (header run, array body, header run, ...)."""
    out: list = []
    run = bytearray()
    for c in parts:
        if isinstance(c, memoryview):
            if run:
                out.append(bytes(run))
                run = bytearray()
            out.append(c)
        else:
            run += c
    if run:
        out.append(bytes(run))
    return out


def iovec_nbytes(bufs: list) -> int:
    """Total byte length of a serialize_iovec result (tx accounting)."""
    return sum(len(b) for b in bufs)


def serialize(msg: Any, version: int = WIRE_V1) -> bytes:
    """Message -> one length-prefixed frame (ready for ``sendall``)."""
    return b"".join(serialize_iovec(msg, version))


def _decode_body(body, version: int = WIRE_VERSION) -> Any:
    r = _Reader(body, version)
    tag = r.u8()
    if tag == _FRAME_ENCODE_SHARE:
        msg = EncodeShare(round=_dec_value(r), worker=_dec_value(r),
                          payload=_dec_value(r))
    elif tag == _FRAME_ROUND:
        if version < WIRE_V2:
            raise WireError(f"unknown frame tag 0x{tag:02x} "
                            f"(wire v2 ROUND on a v1 stream)")
        rnd = _dec_value(r)
        worker = _dec_value(r)
        present = r.u8()
        payload = {k: (_dec_value(r) if present & (1 << i) else None)
                   for i, k in enumerate(ROUND_PAYLOAD_KEYS)}
        msg = EncodeShare(round=rnd, worker=worker, payload=payload)
    elif tag == _FRAME_FROUND:
        if version < WIRE_V2:
            raise WireError(f"unknown frame tag 0x{tag:02x} "
                            f"(wire v2 float ROUND on a v1 stream)")
        rnd = _dec_value(r)
        worker = _dec_value(r)
        present = r.u8()
        payload = {}
        for i, k in enumerate(ROUND_PAYLOAD_KEYS):
            if not present & (1 << i):
                payload[k] = None
            elif r.u8():
                payload[k] = _dec_f32nd(r)
            else:
                payload[k] = _dec_value(r)
        msg = EncodeShare(round=rnd, worker=worker, payload=payload)
    elif tag == _FRAME_FRESULT:
        if version < WIRE_V2:
            raise WireError(f"unknown frame tag 0x{tag:02x} "
                            f"(wire v2 float result on a v1 stream)")
        traced = r.u8()
        msg = WorkerResult(round=_dec_value(r), worker=_dec_value(r),
                           compute_s=_dec_value(r), payload=_dec_f32nd(r),
                           trace=_dec_value(r) if traced else None)
    elif tag == _FRAME_WORKER_RESULT:
        msg = WorkerResult(round=_dec_value(r), worker=_dec_value(r),
                           compute_s=_dec_value(r), payload=_dec_value(r))
    elif tag == _FRAME_WORKER_RESULT_T:
        if version < WIRE_V2:
            raise WireError(f"unknown frame tag 0x{tag:02x} "
                            f"(wire v2 traced result on a v1 stream)")
        msg = WorkerResult(round=_dec_value(r), worker=_dec_value(r),
                           compute_s=_dec_value(r), payload=_dec_value(r),
                           trace=_dec_value(r))
    elif tag == _FRAME_SUB_SHARE:
        msg = SubShare(round=_dec_value(r), phase=_dec_value(r),
                       src=_dec_value(r), dst=_dec_value(r),
                       payload=_dec_value(r))
    elif tag == _FRAME_COMBINE_RESULT:
        msg = CombineResult(round=_dec_value(r), worker=_dec_value(r),
                            compute_s=_dec_value(r), payload=_dec_value(r))
    elif tag == _FRAME_COMBINE_RESULT_T:
        if version < WIRE_V2:
            raise WireError(f"unknown frame tag 0x{tag:02x} "
                            f"(wire v2 traced result on a v1 stream)")
        msg = CombineResult(round=_dec_value(r), worker=_dec_value(r),
                            compute_s=_dec_value(r), payload=_dec_value(r),
                            trace=_dec_value(r))
    elif tag == _FRAME_QUERY:
        msg = Query(qid=_dec_value(r), client=_dec_value(r),
                    sent_at=_dec_value(r), x=_dec_value(r))
    elif tag == _FRAME_PREDICTION:
        msg = Prediction(qid=_dec_value(r), client=_dec_value(r),
                         y=_dec_value(r), latency_s=_dec_value(r))
    elif tag == _FRAME_JOIN:
        if version < WIRE_V2:
            raise WireError(f"unknown frame tag 0x{tag:02x} "
                            f"(wire v2 JOIN on a v1 stream)")
        msg = Join(worker=_dec_value(r), at_round=_dec_value(r),
                   sent_at=_dec_value(r))
    elif tag == _FRAME_EPOCH:
        if version < WIRE_V2:
            raise WireError(f"unknown frame tag 0x{tag:02x} "
                            f"(wire v2 EPOCH on a v1 stream)")
        msg = Epoch(epoch=_dec_value(r), members=_dec_value(r),
                    round=_dec_value(r))
    elif tag == _FRAME_HEARTBEAT:
        msg = Heartbeat(worker=_dec_value(r), sent_at=_dec_value(r))
    elif tag == _FRAME_FORWARD:
        dst = _dec_value(r)
        frame = _dec_value(r)
        if not isinstance(dst, str) or not isinstance(frame, bytes):
            raise WireError("malformed Forward frame")
        msg = Forward(dst=dst, frame=frame)
    elif tag == _FRAME_HELLO:
        msg = Hello(endpoint=_dec_value(r))
    elif tag == _FRAME_HELLO2:
        if version < WIRE_V2:
            raise WireError(f"unknown frame tag 0x{tag:02x} "
                            f"(wire v2 HELLO2 on a v1 stream)")
        endpoint = _dec_value(r)
        ver = _dec_value(r)
        if not isinstance(endpoint, str) or not isinstance(ver, int):
            raise WireError("malformed HELLO2 frame")
        msg = Hello(endpoint=endpoint, version=ver)
    elif tag == _FRAME_RAW:
        msg = Raw(value=_dec_value(r)).value
    else:
        raise WireError(f"unknown frame tag 0x{tag:02x}")
    if r.pos != len(r.data):
        raise WireError(f"{len(r.data) - r.pos} trailing bytes after frame")
    return msg


def deserialize(frame: bytes, version: int = WIRE_VERSION) -> Any:
    """One complete length-prefixed frame -> message.

    Raises WireError on a short, overlong, or malformed frame — a corrupt
    peer must produce a clear error on the spot, never a hang downstream.
    ``version`` is the stream's negotiated version; pass ``WIRE_V1`` to
    decode exactly as a v1 peer would (v2 tags become unknown-tag errors).
    """
    if len(frame) < 4:
        raise WireError(f"frame shorter than its 4-byte length prefix "
                        f"({len(frame)} bytes)")
    (n,) = struct.unpack(">I", frame[:4])
    if n > MAX_FRAME_BYTES:
        raise WireError(f"length prefix {n} exceeds "
                        f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    if len(frame) != 4 + n:
        raise WireError(f"frame length mismatch: prefix says {n} body bytes, "
                        f"got {len(frame) - 4}")
    return _decode_body(memoryview(frame)[4:], version)


class FrameReader:
    """Incremental frame decoder over a byte stream (one per connection).

    ``feed(chunk)`` returns every message completed by the chunk; partial
    frames are buffered until the rest arrives.  A bad length prefix raises
    immediately (a desynchronized stream cannot be resynchronized).

    Zero-copy recv path (DESIGN.md §10): ``feed`` accepts a memoryview over
    the transport's persistent recv scratch buffer and decodes complete
    frames IN PLACE — array payloads are reassembled straight from the
    scratch/stream buffer into their own freshly allocated arrays, with no
    intermediate ``bytes`` materialization.  Only a trailing partial frame
    is buffered.  ``version`` is the negotiated stream version; a v1 reader
    rejects v2 tags like any real v1 peer.
    """

    def __init__(self, version: int = WIRE_VERSION):
        self._buf = bytearray()
        self.version = version

    def _frame_len(self, view) -> int | None:
        """Body length of the frame at ``view``'s start, or None if the
        prefix (or body) isn't fully available yet."""
        if len(view) < 4:
            return None
        n = int.from_bytes(view[:4], "big")
        if n > MAX_FRAME_BYTES:
            raise WireError(f"length prefix {n} exceeds "
                            f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
        return n if len(view) >= 4 + n else None

    def feed(self, chunk) -> list[Any]:
        msgs: list[Any] = []
        mv = chunk if isinstance(chunk, memoryview) else memoryview(chunk)
        if not self._buf:
            # fast path: decode complete frames straight out of the caller's
            # buffer; only the trailing partial frame (if any) is copied in
            pos = 0
            while True:
                n = self._frame_len(mv[pos:])
                if n is None:
                    break
                msgs.append(_decode_body(mv[pos + 4: pos + 4 + n],
                                         self.version))
                pos += 4 + n
            if pos < len(mv):
                self._buf.extend(mv[pos:])
            return msgs
        self._buf.extend(mv)
        while True:
            view = memoryview(self._buf)
            try:
                n = self._frame_len(view)
                if n is not None:
                    msgs.append(_decode_body(view[4: 4 + n], self.version))
            finally:
                view.release()
            if n is None:
                break
            del self._buf[: 4 + n]
        return msgs


# ---------------------------------------------------------------------------
# Structural equality (dataclass == breaks on ndarray payloads)
# ---------------------------------------------------------------------------

def values_equal(a: Any, b: Any) -> bool:
    """Deep equality over the encodable value universe (arrays compared
    elementwise with dtype+shape, NaN == NaN so round-trips are reflexive)."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype != b.dtype or a.shape != b.shape:
            return False
        if a.dtype == object:
            return all(values_equal(x, y)
                       for x, y in zip(a.reshape(-1), b.reshape(-1)))
        return bool(np.array_equal(a, b, equal_nan=a.dtype.kind == "f"))
    if isinstance(a, bool) or isinstance(b, bool):
        return type(a) is type(b) and a == b
    if isinstance(a, float) and isinstance(b, float):
        return (a != a and b != b) or a == b
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(values_equal(x, y) for x, y in zip(a, b)))
    if isinstance(a, dict) and isinstance(b, dict):
        return (a.keys() == b.keys()
                and all(values_equal(v, b[k]) for k, v in a.items()))
    return type(a) is type(b) and a == b


def messages_equal(a: Any, b: Any) -> bool:
    """Field-wise message equality with deep payload comparison."""
    if dataclasses.is_dataclass(a) and dataclasses.is_dataclass(b):
        if type(a) is not type(b):
            return False
        return all(values_equal(getattr(a, f.name), getattr(b, f.name))
                   for f in dataclasses.fields(a))
    return values_equal(a, b)
