"""Wire format for the socket transport: length-prefixed, pickle-free frames.

Every frame is ``u32 body length (big-endian) || body``; the body is a
one-byte frame tag followed by a self-describing, recursively tagged value
encoding.  Three design constraints (DESIGN.md §7):

  * NO PICKLE — the master deserializes bytes from worker processes; the
    decoder only ever constructs ints/floats/strs/arrays/containers and the
    three message dataclasses, never arbitrary objects.
  * EXACT — field arrays travel as dtype/shape header + raw little-endian
    bytes (bit-faithful int32 in [0, p), both the 24-bit P and 30-bit P30);
    python-int payloads (e.g. exact decode-matrix entries from the host
    Lagrange solve) are encoded as sign + big-endian magnitude at arbitrary
    precision, so nothing is silently truncated to 64 bits.
  * FAIL LOUD — malformed or truncated input raises ``WireError`` with a
    description of what broke; it never hangs and never returns garbage.

``serialize``/``deserialize`` round-trip the three message dataclasses
(messages.py) plus two socket-layer frames: HELLO (endpoint registration on
connect) and RAW (an arbitrary encodable value — used by the backend-shared
transport contract tests, which ship plain strings/ints).
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any

import numpy as np

from repro.cluster.messages import (
    CombineResult,
    EncodeShare,
    Heartbeat,
    SubShare,
    WorkerResult,
)

MAX_FRAME_BYTES = 1 << 30        # reject absurd length prefixes outright

# frame tags (first body byte)
_FRAME_ENCODE_SHARE = 0x10
_FRAME_WORKER_RESULT = 0x11
_FRAME_HEARTBEAT = 0x12
_FRAME_HELLO = 0x13
_FRAME_RAW = 0x14
_FRAME_FORWARD = 0x15
_FRAME_SUB_SHARE = 0x16
_FRAME_COMBINE_RESULT = 0x17

# value tags
_T_NONE = 0x00
_T_FALSE = 0x01
_T_TRUE = 0x02
_T_INT = 0x03
_T_FLOAT = 0x04
_T_STR = 0x05
_T_BYTES = 0x06
_T_NDARRAY = 0x07
_T_INTARRAY = 0x08               # object-dtype array of exact python ints
_T_LIST = 0x09
_T_TUPLE = 0x0A
_T_DICT = 0x0B


class WireError(ValueError):
    """Malformed, truncated, or unencodable wire data."""


@dataclasses.dataclass(frozen=True)
class Hello:
    """Connection registration: the first frame a client sends names its
    endpoint ("worker/3") so the master can route by destination."""
    endpoint: str


@dataclasses.dataclass(frozen=True)
class Raw:
    """An arbitrary encodable value as a message (transport contract tests
    exercise the backends with plain strings/ints, not protocol messages)."""
    value: Any


@dataclasses.dataclass(frozen=True)
class Forward:
    """Socket-layer relay envelope: deliver ``frame`` (one complete
    serialized frame) to endpoint ``dst``.

    The socket topology is a star — workers hold one connection, to the
    master — so worker->worker traffic (SubShare, DESIGN.md §7) rides to the
    master wrapped in a Forward, and the master writes the inner frame bytes
    to the destination connection VERBATIM (no re-serialization on the relay
    hop).  Never surfaced to recv(): the transport consumes it.
    """
    dst: str
    frame: bytes


# ---------------------------------------------------------------------------
# Value encoding
# ---------------------------------------------------------------------------

def _enc_u32(n: int) -> bytes:
    return struct.pack(">I", n)


def _enc_value(v: Any, out: list[bytes]) -> None:
    if v is None:
        out.append(bytes([_T_NONE]))
    elif isinstance(v, bool):
        out.append(bytes([_T_TRUE if v else _T_FALSE]))
    elif isinstance(v, (int, np.integer)):
        v = int(v)
        mag = abs(v)
        body = mag.to_bytes((mag.bit_length() + 7) // 8, "big")
        out.append(bytes([_T_INT, 1 if v < 0 else 0]) + _enc_u32(len(body))
                   + body)
    elif isinstance(v, (float, np.floating)):
        out.append(bytes([_T_FLOAT]) + struct.pack(">d", float(v)))
    elif isinstance(v, str):
        b = v.encode("utf-8")
        out.append(bytes([_T_STR]) + _enc_u32(len(b)) + b)
    elif isinstance(v, bytes):
        out.append(bytes([_T_BYTES]) + _enc_u32(len(v)) + v)
    elif isinstance(v, np.ndarray) and v.dtype == object:
        # exact python-int matrices (host Lagrange solves): element-wise
        # arbitrary-precision ints, never truncated to a machine word.
        out.append(bytes([_T_INTARRAY, v.ndim]))
        for dim in v.shape:
            out.append(_enc_u32(dim))
        for e in v.reshape(-1):
            if not isinstance(e, (int, np.integer)):
                raise WireError(
                    f"object arrays may only hold ints, got {type(e).__name__}")
            _enc_value(int(e), out)
    elif isinstance(v, np.ndarray):
        dt = v.dtype.newbyteorder("<")
        ds = dt.str.encode("ascii")
        out.append(bytes([_T_NDARRAY, len(ds)]) + ds + bytes([v.ndim]))
        for dim in v.shape:
            out.append(_enc_u32(dim))
        out.append(np.ascontiguousarray(v, dtype=dt).tobytes())
    elif isinstance(v, list):
        out.append(bytes([_T_LIST]) + _enc_u32(len(v)))
        for e in v:
            _enc_value(e, out)
    elif isinstance(v, tuple):
        out.append(bytes([_T_TUPLE]) + _enc_u32(len(v)))
        for e in v:
            _enc_value(e, out)
    elif isinstance(v, dict):
        out.append(bytes([_T_DICT]) + _enc_u32(len(v)))
        for k, e in v.items():
            if not isinstance(k, str):
                raise WireError(f"dict keys must be str, got {type(k).__name__}")
            _enc_value(k, out)
            _enc_value(e, out)
    else:
        # device arrays (jax) quack like arrays; anything else is a bug.
        arr = np.asarray(v)
        if arr.dtype == object:
            raise WireError(f"cannot encode {type(v).__name__}")
        _enc_value(arr, out)


class _Reader:
    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0

    def take(self, n: int) -> bytes:
        if n < 0 or self.pos + n > len(self.data):
            raise WireError(
                f"truncated frame: wanted {n} bytes at offset {self.pos}, "
                f"frame has {len(self.data)}")
        b = self.data[self.pos: self.pos + n]
        self.pos += n
        return b

    def u8(self) -> int:
        return self.take(1)[0]

    def u32(self) -> int:
        return struct.unpack(">I", self.take(4))[0]


def _dec_value(r: _Reader) -> Any:
    tag = r.u8()
    if tag == _T_NONE:
        return None
    if tag == _T_FALSE:
        return False
    if tag == _T_TRUE:
        return True
    if tag == _T_INT:
        neg = r.u8()
        mag = int.from_bytes(r.take(r.u32()), "big")
        return -mag if neg else mag
    if tag == _T_FLOAT:
        return struct.unpack(">d", r.take(8))[0]
    if tag == _T_STR:
        return r.take(r.u32()).decode("utf-8")
    if tag == _T_BYTES:
        return r.take(r.u32())
    if tag == _T_NDARRAY:
        # the fail-loud contract covers garbage INSIDE fields too: a bogus
        # dtype string or impossible shape must surface as WireError, not
        # as whatever numpy happens to raise
        try:
            dt = np.dtype(r.take(r.u8()).decode("ascii"))
        except Exception as e:
            raise WireError(f"malformed ndarray dtype: {e}") from None
        shape = tuple(r.u32() for _ in range(r.u8()))
        n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        try:
            arr = np.frombuffer(r.take(n), dtype=dt).reshape(shape)
        except WireError:
            raise
        except Exception as e:
            raise WireError(f"malformed ndarray body: {e}") from None
        return arr.copy()             # writable, detached from the buffer
    if tag == _T_INTARRAY:
        shape = tuple(r.u32() for _ in range(r.u8()))
        n = int(np.prod(shape, dtype=np.int64))
        arr = np.empty(n, dtype=object)
        for i in range(n):
            arr[i] = _dec_value(r)
        return arr.reshape(shape)
    if tag == _T_LIST:
        return [_dec_value(r) for _ in range(r.u32())]
    if tag == _T_TUPLE:
        return tuple(_dec_value(r) for _ in range(r.u32()))
    if tag == _T_DICT:
        return {_dec_value(r): _dec_value(r) for _ in range(r.u32())}
    raise WireError(f"unknown value tag 0x{tag:02x}")


# ---------------------------------------------------------------------------
# Message frames
# ---------------------------------------------------------------------------

def serialize(msg: Any) -> bytes:
    """Message -> one length-prefixed frame (ready for ``sendall``)."""
    out: list[bytes] = []
    if isinstance(msg, EncodeShare):
        out.append(bytes([_FRAME_ENCODE_SHARE]))
        _enc_value(msg.round, out)
        _enc_value(msg.worker, out)
        _enc_value(msg.payload, out)
    elif isinstance(msg, WorkerResult):
        out.append(bytes([_FRAME_WORKER_RESULT]))
        _enc_value(msg.round, out)
        _enc_value(msg.worker, out)
        _enc_value(msg.compute_s, out)
        _enc_value(msg.payload, out)
    elif isinstance(msg, SubShare):
        out.append(bytes([_FRAME_SUB_SHARE]))
        _enc_value(msg.round, out)
        _enc_value(msg.phase, out)
        _enc_value(msg.src, out)
        _enc_value(msg.dst, out)
        _enc_value(msg.payload, out)
    elif isinstance(msg, CombineResult):
        out.append(bytes([_FRAME_COMBINE_RESULT]))
        _enc_value(msg.round, out)
        _enc_value(msg.worker, out)
        _enc_value(msg.compute_s, out)
        _enc_value(msg.payload, out)
    elif isinstance(msg, Heartbeat):
        out.append(bytes([_FRAME_HEARTBEAT]))
        _enc_value(msg.worker, out)
        _enc_value(msg.sent_at, out)
    elif isinstance(msg, Forward):
        out.append(bytes([_FRAME_FORWARD]))
        _enc_value(msg.dst, out)
        _enc_value(msg.frame, out)
    elif isinstance(msg, Hello):
        out.append(bytes([_FRAME_HELLO]))
        _enc_value(msg.endpoint, out)
    else:
        out.append(bytes([_FRAME_RAW]))
        _enc_value(msg, out)
    body = b"".join(out)
    if len(body) > MAX_FRAME_BYTES:
        raise WireError(f"frame body of {len(body)} bytes exceeds "
                        f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    return _enc_u32(len(body)) + body


def _decode_body(body: bytes) -> Any:
    r = _Reader(body)
    tag = r.u8()
    if tag == _FRAME_ENCODE_SHARE:
        msg = EncodeShare(round=_dec_value(r), worker=_dec_value(r),
                          payload=_dec_value(r))
    elif tag == _FRAME_WORKER_RESULT:
        msg = WorkerResult(round=_dec_value(r), worker=_dec_value(r),
                           compute_s=_dec_value(r), payload=_dec_value(r))
    elif tag == _FRAME_SUB_SHARE:
        msg = SubShare(round=_dec_value(r), phase=_dec_value(r),
                       src=_dec_value(r), dst=_dec_value(r),
                       payload=_dec_value(r))
    elif tag == _FRAME_COMBINE_RESULT:
        msg = CombineResult(round=_dec_value(r), worker=_dec_value(r),
                            compute_s=_dec_value(r), payload=_dec_value(r))
    elif tag == _FRAME_HEARTBEAT:
        msg = Heartbeat(worker=_dec_value(r), sent_at=_dec_value(r))
    elif tag == _FRAME_FORWARD:
        dst = _dec_value(r)
        frame = _dec_value(r)
        if not isinstance(dst, str) or not isinstance(frame, bytes):
            raise WireError("malformed Forward frame")
        msg = Forward(dst=dst, frame=frame)
    elif tag == _FRAME_HELLO:
        msg = Hello(endpoint=_dec_value(r))
    elif tag == _FRAME_RAW:
        msg = Raw(value=_dec_value(r)).value
    else:
        raise WireError(f"unknown frame tag 0x{tag:02x}")
    if r.pos != len(body):
        raise WireError(f"{len(body) - r.pos} trailing bytes after frame")
    return msg


def deserialize(frame: bytes) -> Any:
    """One complete length-prefixed frame -> message.

    Raises WireError on a short, overlong, or malformed frame — a corrupt
    peer must produce a clear error on the spot, never a hang downstream.
    """
    if len(frame) < 4:
        raise WireError(f"frame shorter than its 4-byte length prefix "
                        f"({len(frame)} bytes)")
    (n,) = struct.unpack(">I", frame[:4])
    if n > MAX_FRAME_BYTES:
        raise WireError(f"length prefix {n} exceeds "
                        f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    if len(frame) != 4 + n:
        raise WireError(f"frame length mismatch: prefix says {n} body bytes, "
                        f"got {len(frame) - 4}")
    return _decode_body(frame[4:])


class FrameReader:
    """Incremental frame decoder over a byte stream (one per connection).

    ``feed(chunk)`` returns every message completed by the chunk; partial
    frames are buffered until the rest arrives.  A bad length prefix raises
    immediately (a desynchronized stream cannot be resynchronized).
    """

    def __init__(self):
        self._buf = bytearray()

    def feed(self, chunk: bytes) -> list[Any]:
        self._buf.extend(chunk)
        msgs = []
        while len(self._buf) >= 4:
            (n,) = struct.unpack(">I", self._buf[:4])
            if n > MAX_FRAME_BYTES:
                raise WireError(f"length prefix {n} exceeds "
                                f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
            if len(self._buf) < 4 + n:
                break
            msgs.append(_decode_body(bytes(self._buf[4: 4 + n])))
            del self._buf[: 4 + n]
        return msgs


# ---------------------------------------------------------------------------
# Structural equality (dataclass == breaks on ndarray payloads)
# ---------------------------------------------------------------------------

def values_equal(a: Any, b: Any) -> bool:
    """Deep equality over the encodable value universe (arrays compared
    elementwise with dtype+shape, NaN == NaN so round-trips are reflexive)."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype != b.dtype or a.shape != b.shape:
            return False
        if a.dtype == object:
            return all(values_equal(x, y)
                       for x, y in zip(a.reshape(-1), b.reshape(-1)))
        return bool(np.array_equal(a, b, equal_nan=a.dtype.kind == "f"))
    if isinstance(a, bool) or isinstance(b, bool):
        return type(a) is type(b) and a == b
    if isinstance(a, float) and isinstance(b, float):
        return (a != a and b != b) or a == b
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(values_equal(x, y) for x, y in zip(a, b)))
    if isinstance(a, dict) and isinstance(b, dict):
        return (a.keys() == b.keys()
                and all(values_equal(v, b[k]) for k, v in a.items()))
    return type(a) is type(b) and a == b


def messages_equal(a: Any, b: Any) -> bool:
    """Field-wise message equality with deep payload comparison."""
    if dataclasses.is_dataclass(a) and dataclasses.is_dataclass(b):
        if type(a) is not type(b):
            return False
        return all(values_equal(getattr(a, f.name), getattr(b, f.name))
                   for f in dataclasses.fields(a))
    return values_equal(a, b)
