"""Protocol micro-benchmark: worker-step throughput per compute backend.

Times one full worker round (encode weights -> all N worker polynomials ->
survivor decode) for the vmap and shard backends, and the fused-vs-unfused
worker computation, across (K, T, r, c) settings.  Emits CSV rows (see
benchmarks/common.py) and writes BENCH_protocol.json so future PRs have a
perf trajectory.

Fused-kernel caveat (DESIGN.md §4): on CPU there is no Mosaic compiler —
Pallas ``interpret=True`` is a correctness simulator, orders of magnitude
slower than anything, so timing it says nothing about the TPU kernel.  On
CPU the fused path is therefore timed via its jnp fallback and the JSON
records ``"fused_backend": "jnp-fallback"``; on a TPU host the same script
times the real Mosaic kernel (``"fused_backend": "pallas"``).

    PYTHONPATH=src python benchmarks/bench_protocol.py [--out BENCH_protocol.json]
"""
from __future__ import annotations

import argparse
import json
import os

# one host device per worker so the shard backend is a real 8-way mesh;
# must happen before jax initializes.
N_WORKERS = 8
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={N_WORKERS}")

import jax
import jax.numpy as jnp
import numpy as np

from common import emit, time_fn

from repro.core import protocol, sigmoid_poly
from repro.kernels import ops as kernel_ops

# (K, T, r, c) sweeps at N=8; threshold (2r+1)(K+T-1)+1 must stay <= 8.
DEFAULT_SETTINGS = [
    (2, 1, 1, 1),    # the paper's binary Case 2 at N=8
    (2, 1, 1, 4),    # multi-class amortization over the same shares
    (2, 1, 1, 10),
    (3, 0, 1, 4),    # more parallelism, no privacy masks
]
DEFAULT_M, DEFAULT_D = 1024, 256


def bench_setting(K: int, T: int, r: int, c: int, m: int, d: int,
                  mesh) -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (m, d))
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(r, 2, 4, 6), jnp.int32)
    w = jnp.zeros((d,) if c == 1 else (d, c), jnp.float32)
    entry = {"N": N_WORKERS, "K": K, "T": T, "r": r, "c": c,
             "backends_us": {}}

    def round_fn(cfg):
        state = protocol.setup(cfg, key, x, jnp.zeros((m,)))
        dmat = protocol.make_decode_matrix(cfg, np.arange(cfg.threshold))
        order = jnp.arange(cfg.threshold, dtype=jnp.int32)

        @jax.jit
        def one_round(k, wv):
            w_shares = protocol.encode_weights(cfg, k, wv)
            res = protocol.all_worker_results(cfg, cbar, state.x_shares,
                                              w_shares)
            return protocol.decode_gradient(cfg, jnp.take(res, order, 0), dmat)

        return one_round

    for backend in ("vmap", "shard"):
        cfg = protocol.CPMLConfig(N=N_WORKERS, K=K, T=T, r=r, c=c,
                                  backend=backend)
        fn = round_fn(cfg)
        if backend == "shard":
            with mesh:
                us = time_fn(fn, key, w)
        else:
            us = time_fn(fn, key, w)
        entry["backends_us"][backend] = us
        rows = m // K * K
        emit(f"protocol_round/{backend}/K{K}_T{T}_r{r}_c{c}", us,
             f"{rows * c / (us / 1e6):.3e} row-heads/s")

    # fused vs unfused worker computation (ONE worker's share)
    mk = m // K
    rng = np.random.default_rng(0)
    p = cfg.p
    xs = jnp.asarray(rng.integers(0, p, (mk, d)), jnp.int32)
    ws = jnp.asarray(rng.integers(0, p, (d, c, r)), jnp.int32)
    pallas_ok = jax.default_backend() != "cpu"

    def unfused(a, b):
        return kernel_ops.coded_grad_mc(a, b, cbar, p, use_pallas=False)

    def fused(a, b):
        return kernel_ops.coded_grad_mc(a, b, cbar, p, use_pallas=pallas_ok)

    entry["worker_unfused_us"] = time_fn(unfused, xs, ws, warmup=2, iters=5)
    entry["worker_fused_us"] = time_fn(fused, xs, ws, warmup=2, iters=5)
    entry["fused_backend"] = "pallas" if pallas_ok else "jnp-fallback"
    entry["fused_not_slower"] = bool(
        entry["worker_fused_us"] <= entry["worker_unfused_us"] * 1.15)
    emit(f"worker_fused/K{K}_T{T}_r{r}_c{c}", entry["worker_fused_us"],
         f"vs unfused {entry['worker_unfused_us']:.1f}us "
         f"({entry['fused_backend']})")
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_protocol.json"))
    ap.add_argument("--m", type=int, default=DEFAULT_M)
    ap.add_argument("--d", type=int, default=DEFAULT_D)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + the first two settings (CI tier-1:"
                         " exercises the full bench path and enforces the "
                         "acceptance flags on every push)")
    args = ap.parse_args(argv)

    settings_sweep = DEFAULT_SETTINGS
    if args.smoke:
        settings_sweep = DEFAULT_SETTINGS[:2]
        if args.m == DEFAULT_M:
            args.m = 256
        if args.d == DEFAULT_D:
            args.d = 64
    mesh = jax.make_mesh((N_WORKERS,), ("workers",))
    settings = [bench_setting(K, T, r, c, args.m, args.d, mesh)
                for (K, T, r, c) in settings_sweep]
    report = {
        "device": jax.default_backend(),
        "pallas_compiled": jax.default_backend() != "cpu",
        "shapes": {"m": args.m, "d": args.d, "N": N_WORKERS},
        "smoke": args.smoke,
        "settings": settings,
        "kernel_not_slower": bool(all(s["fused_not_slower"]
                                      for s in settings)),
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out}  kernel_not_slower={report['kernel_not_slower']}")
    # the acceptance flags gate CI: a fused kernel that got slower than its
    # unfused oracle (beyond the 1.15x noise headroom) fails the job
    return 0 if report["kernel_not_slower"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
