"""Benchmark driver: one section per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV.  Default scale is CPU-sized; set
--full for the paper's (m, d) = (12396, 1568) (slow on 1 core).

Sections:
  table1_*   run-time breakdown, MPC vs CPML case1/case2 (paper Tables 1-6)
  fig2_*     total training-time scaling vs N + speedup   (paper Figs 2/5)
  fig3_*     accuracy CPML vs conventional logreg         (paper Fig 3)
  fig4_*     convergence (cross-entropy)                  (paper Fig 4)
  kernel_*   Pallas kernels vs jnp reference path
  roofline_* per-cell dry-run roofline terms (reads benchmarks/results/)
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import phases
from benchmarks.common import emit, time_fn
from repro.core import field, mpc_baseline as mpc, protocol, sigmoid_poly
from repro.data import synthetic


def bench_tables_and_fig2(m: int, d: int, Ns: list[int], iters: int):
    x, y = synthetic.mnist_like(jax.random.PRNGKey(42), m=m, d=d)
    for N in Ns:
        rows = {}
        for name, times in [
            ("mpc", phases.mpc_phase_times(
                mpc.MPCConfig(N=N, T=max(1, (N - 1) // 2)), x, y, iters)),
            ("cpml_case1", phases.cpml_phase_times(phases.case1(N), x, y,
                                                   iters)),
            ("cpml_case2", phases.cpml_phase_times(phases.case2(N), x, y,
                                                   iters)),
        ]:
            rows[name] = times
            for phase in ("encode", "comm", "comp", "total"):
                emit(f"table1_N{N}_{name}_{phase}", times[phase] * 1e6,
                     f"m={m};d={d};iters={iters}")
        sp1 = rows["mpc"]["total"] / rows["cpml_case1"]["total"]
        sp2 = rows["mpc"]["total"] / rows["cpml_case2"]["total"]
        emit(f"fig2_N{N}_speedup_case1", rows["cpml_case1"]["total"] * 1e6,
             f"speedup_vs_mpc={sp1:.2f}x")
        emit(f"fig2_N{N}_speedup_case2", rows["cpml_case2"]["total"] * 1e6,
             f"speedup_vs_mpc={sp2:.2f}x")


def bench_fig3_fig4(m: int, d: int, iters: int = 25):
    x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=m, d=d, margin=12.0)
    cfg = phases.case2(8)
    import time
    t0 = time.perf_counter()
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=iters,
                             eval_every=5)
    dt = time.perf_counter() - t0
    state = protocol.setup(cfg, jax.random.PRNGKey(7), x, y)
    eta = protocol.lipschitz_eta(state.xq_real)
    w2 = jnp.zeros(x.shape[1])
    xq = state.xq_real[:m]
    losses_ref = []
    for t in range(iters):
        w2 = w2 - eta * (xq.T @ (protocol.sigmoid(xq @ w2) - y)) / m
        if (t + 1) % 5 == 0:
            l, a = protocol.loss_and_accuracy(w2, xq, y)
            losses_ref.append((t + 1, float(l), float(a)))
    for h, (it, lr_, ar_) in zip(hist, losses_ref):
        emit(f"fig4_iter{h['iter']}", dt / iters * 1e6,
             f"loss_cpml={h['loss']:.4f};loss_conv={lr_:.4f}")
    emit("fig3_accuracy", dt / iters * 1e6,
         f"acc_cpml={hist[-1]['acc']:.4f};acc_conv={losses_ref[-1][2]:.4f}")


def bench_kernels():
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    for (M, K, N) in [(256, 512, 64), (512, 1024, 2)]:
        a = jnp.asarray(rng.integers(0, field.P, (M, K)), jnp.int32)
        b = jnp.asarray(rng.integers(0, field.P, (K, N)), jnp.int32)
        us_ref = time_fn(lambda: ops.modmatmul(a, b, use_pallas=False))
        emit(f"kernel_modmatmul_ref_{M}x{K}x{N}", us_ref,
             "jnp-limb path (XLA CPU)")
        us_pal = time_fn(lambda: ops.modmatmul(a, b, use_pallas=True))
        emit(f"kernel_modmatmul_pallas_{M}x{K}x{N}", us_pal,
             "interpret=True (correctness mode; TPU target)")
    x = jnp.asarray(rng.integers(0, field.P, (512, 256)), jnp.int32)
    w = jnp.asarray(rng.integers(0, field.P, (256, 1)), jnp.int32)
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(1, 2, 4, 6), jnp.int32)
    us = time_fn(lambda: ops.coded_grad(x, w, cbar, use_pallas=False))
    emit("kernel_coded_grad_ref_512x256", us, "unfused jnp path")
    us = time_fn(lambda: ops.coded_grad(x, w, cbar, use_pallas=True))
    emit("kernel_coded_grad_pallas_512x256", us,
         "fused single-pass (interpret)")


def bench_roofline(results_dir: str):
    cells = sorted(glob.glob(os.path.join(results_dir, "dryrun_*.json")))
    if not cells:
        emit("roofline_missing", 0.0, "run repro.launch.dryrun --all first")
        return
    for path in cells:
        with open(path) as f:
            c = json.load(f)
        tag = f"{c['arch']}__{c['shape']}__{c['mesh']}"
        if c["status"] != "ok":
            emit(f"roofline_{tag}", 0.0, c["status"])
            continue
        t = c["roofline_terms_s"]
        emit(f"roofline_{tag}", c["step_time_bound_s"] * 1e6,
             f"dominant={c['dominant']};compute={t['compute_s']:.4f}"
             f";memory={t['memory_s']:.4f};collective={t['collective_s']:.4f}"
             f";useful={c.get('useful_ratio') or 0:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (m,d)=(12396,1568); slow on CPU")
    ap.add_argument("--sections", default="tables,figs,kernels,roofline")
    ap.add_argument("--results-dir", default="benchmarks/results_final")
    args = ap.parse_args()
    m, d = (12396, 1568) if args.full else (1200, 128)
    Ns = [10, 25, 40] if args.full else [10, 25]
    iters = 5 if args.full else 3
    sections = set(args.sections.split(","))
    print("name,us_per_call,derived")
    if "tables" in sections:
        bench_tables_and_fig2(m, d, Ns, iters)
    if "figs" in sections:
        bench_fig3_fig4(m if args.full else 800, d if args.full else 64)
    if "kernels" in sections:
        bench_kernels()
    if "roofline" in sections:
        bench_roofline(args.results_dir)


if __name__ == "__main__":
    main()
