"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> diff.

Each invocation re-runs one dry-run cell with RunConfig overrides and prints
the roofline-term deltas vs the recorded baseline JSON.  Results land in
benchmarks/results/hillclimb_<cell>__<tag>.json so EXPERIMENTS.md §Perf can
cite exact numbers.

  PYTHONPATH=src:. python -m benchmarks.hillclimb \
      --arch falcon-mamba-7b --shape prefill_32k --tag bf16-ssm \
      --set ssm_dtype=bf16 attn_dtype=bf16
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json

from repro.configs.base import SHAPES, RunConfig


def parse_overrides(pairs):
    out = {}
    for pair in pairs:
        key, val = pair.split("=", 1)
        field_types = {f.name: f.type for f in
                       dataclasses.fields(RunConfig)}
        t = field_types[key]
        if t == "int" or t is int:
            val = int(val)
        elif t == "bool" or t is bool:
            val = val.lower() in ("1", "true", "yes")
        elif t == "float" or t is float:
            val = float(val)
        out[key] = val
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args()

    from repro.launch import dryrun as D
    overrides = parse_overrides(args.set)
    cfgmod = D.registry.get_config(args.arch)
    rc = dataclasses.replace(D.default_rc(cfgmod, SHAPES[args.shape]),
                             **overrides)
    cell = D.run_cell(args.arch, args.shape, multi_pod=False, rc=rc,
                      verbose=False)
    base_path = os.path.join(args.out,
                             f"dryrun_{args.arch}__{args.shape}__16x16.json")
    with open(base_path) as f:
        base = json.load(f)
    out_path = os.path.join(
        args.out, f"hillclimb_{args.arch}__{args.shape}__{args.tag}.json")
    cell["overrides"] = overrides
    cell["tag"] = args.tag
    with open(out_path, "w") as f:
        json.dump(cell, f, indent=2)

    print(f"\n=== {args.arch} x {args.shape} [{args.tag}] "
          f"{overrides} ===")
    if cell["status"] != "ok":
        print("FAILED:", cell.get("error"))
        return 1
    for term in ("compute_s", "memory_s", "collective_s"):
        b = base["roofline_terms_s"][term]
        n = cell["roofline_terms_s"][term]
        delta = (n - b) / b * 100 if b else float("nan")
        print(f"{term:14s} {b:10.4f} -> {n:10.4f}  ({delta:+.1f}%)")
    bb, nb = base["step_time_bound_s"], cell["step_time_bound_s"]
    print(f"{'bound':14s} {bb:10.4f} -> {nb:10.4f}  "
          f"({(nb-bb)/bb*100:+.1f}%)   dominant: {base['dominant']} -> "
          f"{cell['dominant']}")
    print(f"{'useful_ratio':14s} {base['useful_ratio']:.3f} -> "
          f"{cell['useful_ratio']:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
