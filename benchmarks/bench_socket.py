"""Socket-cluster benchmark: real multi-process rounds vs the simulation.

Two questions about the live backend (DESIGN.md §7):

  1. PER-ROUND OVERHEAD — what does a real round cost end-to-end (encode ->
     serialize -> TCP -> worker compute -> TCP -> decode) compared to the
     same round computed in-process on the master?  The in-process figure
     is measured WALL-clock (the simulated clock is free; the master still
     pays the on-device round), so the difference is the transport tax:
     framing + sockets + process scheduling.
  2. FIRST-T vs WAIT-ALL — with a worker that REALLY sleeps before every
     reply (an injected straggler process), how much does decoding at the
     fastest ``threshold`` responders save over waiting for everyone?
     ``collect_all`` keeps each round open so both completion times are
     observed on the same wall clock — the paper's Fig. 5 effect with real
     network and real stragglers, not sampled latencies.
  3. PIPELINED vs SEQUENTIAL — the same straggled cluster driven with
     ``pipeline="full"`` (DESIGN.md §9): a prefetch thread builds the next
     round's masks/batch/decode-coefficients during the wait and the
     streaming decoder folds shares as they arrive (the stable fast subset
     makes its prediction hit).  The master-side encode+decode component
     of the critical path is measured per round on the wall clock;
     acceptance gates on the machinery engaging and on bit-identity (the
     deterministic pipelined-not-slower contract is bench_cluster.py's,
     on the simulated clock — see the pipeline_cmp comment).
  4. CPML vs MEASURED MPC — the BGW baseline run head-to-head over the
     SAME sockets with the same sleeping straggler (cluster/mpc_runner.py):
     the straggler's sleep gates every reshare barrier AND its final share
     send, so each BGW iteration pays it r+1 times while the coded round
     skips the sleeper entirely.  ``speedup_vs_mpc_live`` is that ratio on
     a wall clock, with worker processes, frames, and relays included —
     bit-identity to the single-host oracle is part of the acceptance.

  5. WIRE v1 vs v2 — the same live run on the legacy wire format
     (``local_socket_cluster(wire_version=1)``): v2's packed/coalesced
     frames (DESIGN.md §10) must ship strictly fewer bytes per round while
     both stay bit-identical.  Every socket entry reports bytes-on-wire
     from the scheduler's per-round ``wire_totals()`` deltas.
  6. SCALE-N (``--scale-n``) — the fleet-size trend: N=16/32/64 worker
     processes on a tiny problem (the 64-point on trimmed iterations
     unless ``--full``), gated on bit-identity and a sanity ceiling on
     per-round wall time.
  7. FLIGHT RECORDER ON vs OFF — the straggled run repeated with the span
     recorder enabled (DESIGN.md §11): worker processes ship their
     recv/compute/serialize spans over the v2 TRACE wire field, the
     master's per-round spans must reconcile with wait_stats, training
     stays bit-identical, and the traced full-round wall time stays
     within a generous bound of the untraced run (the tight ≤5% overhead
     gate lives in bench_cluster.py on the simulated clock, where the
     comparison is deterministic).

    PYTHONPATH=src python benchmarks/bench_socket.py [--smoke] [--out PATH]
                                                     [--scale-n] [--full]

Writes BENCH_socket.json; CI's slow job runs --smoke and uploads the
artifact alongside BENCH_cluster.json.  Round 0 is excluded from per-round
stats (worker-side jit warmup).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from common import emit

from repro.cluster import (ClusterRunner, DeterministicLatency,
                           MPCClusterRunner, wait_summary)
from repro.core import mpc_baseline, protocol
from repro.data import synthetic
from repro.launch.cpml_cluster import local_socket_cluster


def steady_rounds(runner) -> list:
    """Per-round records minus round 0 (jit warmup on master + workers)."""
    return [r for t, r in sorted(runner.records.items()) if t >= 1]


def bench_inprocess(cfg, x, y, iters: int) -> dict:
    """Wall-clock cost of simulated rounds: on-device compute, no wire."""
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                           DeterministicLatency(base=1e-6, skew=0.0))
    runner.step_round(0, iters)                  # warmup outside the clock
    t0 = time.perf_counter()
    for t in range(1, iters):
        runner.step_round(t, iters)
    wall = time.perf_counter() - t0
    per_round = wall / (iters - 1)
    emit("socket/inprocess_round", per_round * 1e6, "wall s/round, no wire")
    return {"wall_s_per_round": per_round, "rounds": iters - 1}


def bench_socket(cfg, x, y, iters: int, sleep_s: float | None,
                 pipeline: str = "off", wire_version: int = 2,
                 connect_timeout_s: float = 60.0,
                 traced: bool = False) -> dict:
    recorder = None
    if traced:
        from repro.obs.trace import Recorder
        recorder = Recorder()
    straggler = {cfg.N - 1: sleep_s} if sleep_s else None
    with local_socket_cluster(cfg.N, sleep_s=straggler,
                              wire_version=wire_version,
                              connect_timeout_s=connect_timeout_s) as tr:
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                               latency=None, transport=tr,
                               round_timeout_s=300.0,
                               collect_all=sleep_s is not None,
                               pipeline=pipeline,
                               recorder=recorder)
        runner.provision(timeout_s=max(60.0, connect_timeout_s))
        t0 = time.perf_counter()
        w = runner.run(iters)
        wall = time.perf_counter() - t0
        stats = runner.wait_stats()
        runner.shutdown_workers()
        # bit-identity is part of the benchmark contract: a fast wrong
        # backend is worthless
        w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                            iters=iters,
                                            survivor_fn=runner.survivor_fn())
        identical = bool((np.asarray(w) == np.asarray(w_ref)).all())
    recs = steady_rounds(runner)
    coded = wait_summary([r.coded_wait_s for r in recs])
    # full-round duration = dispatch-to-dispatch span: unlike coded_T (which
    # stops at the threshold-th arrival) this includes the master-side
    # encode/serialize before t0 and decode/update after collection — the
    # like-for-like figure against the in-process step_round wall time.
    starts = [runner.traces[t].t_start for t in sorted(runner.traces)]
    full = np.diff(starts)[1:]               # drop the warmup round's span
    entry = {
        "wall_s_total": wall,
        "coded_T": coded,
        "full_round": wait_summary(full),
        # measured master-side components (DESIGN.md §9): where each
        # steady-state round's non-wait time went
        "encode": wait_summary([r.encode_s for r in recs]),
        "decode": wait_summary([r.decode_s for r in recs]),
        "critical_path": wait_summary([r.critical_path_s for r in recs]),
        "streamed_rounds": int(sum(r.streamed for r in recs)),
        "prefetched_rounds": int(sum(r.prefetched for r in recs)),
        "pipeline": pipeline,
        "bit_identical": identical,
        "rounds": len(recs),
        # bytes on the wire (satellite telemetry, DESIGN.md §10): per-round
        # tx/rx from the scheduler's wire_totals() deltas + run totals
        "wire_version": wire_version,
        "wire": {
            "tx_bytes_per_round": stats["wire_tx_bytes"]["mean"],
            "rx_bytes_per_round": stats["wire_rx_bytes"]["mean"],
            "tx_frames_per_round": stats["wire_tx_frames"]["mean"],
            "totals": stats.get("wire_totals", {}),
        },
    }
    if traced:
        # flight-recorder extras (DESIGN.md §11): span volume, worker-side
        # spans shipped over the v2 TRACE field, and the reconciliation of
        # per-round critical-path spans against wait_stats on a wall clock
        from repro.obs.export import round_summaries
        span_cp = sum(r["critical_path"]
                      for r in round_summaries(runner.obs))
        stats_cp = stats["critical_path"]["total"]
        entry["trace"] = {
            "spans": len(runner.obs.spans),
            "open_spans": len(runner.obs.open_spans()),
            "worker_processes": len({s.process for s in runner.obs.spans
                                     if s.process.startswith("worker")}),
            "span_critical_path_s": float(span_cp),
            "stats_critical_path_s": float(stats_cp),
            "reconciles": bool(abs(span_cp - stats_cp)
                               <= 1e-9 * max(1.0, abs(stats_cp))),
        }
    if sleep_s:
        allw = [r.all_wait_s for r in recs if math.isfinite(r.all_wait_s)]
        entry["wait_all"] = wait_summary(allw)
        entry["straggler_sleep_s"] = sleep_s
        emit(f"socket/straggler_round[{pipeline}]"
             + ("[traced]" if traced else ""), coded["mean"] * 1e6,
             f"vs wait_all {entry['wait_all']['mean']:.3f}s "
             f"(sleep {sleep_s}s)")
    else:
        emit(f"socket/live_round[{pipeline}]", coded["mean"] * 1e6,
             f"bit_identical={identical}")
    return entry


def bench_socket_mpc(cfg, x, y, iters: int, sleep_s: float) -> dict:
    """The measured MPC half of the head-to-head: BGW over real sockets
    with the same sleeping straggler the coded benchmark rides through."""
    straggler = {cfg.N - 1: sleep_s}
    with local_socket_cluster(cfg.N, sleep_s=straggler) as tr:
        runner = MPCClusterRunner(cfg, jax.random.PRNGKey(7), x, y, None,
                                  transport=tr, round_timeout_s=300.0)
        runner.provision()
        t0 = time.perf_counter()
        w = runner.run(iters)
        wall = time.perf_counter() - t0
        runner.shutdown_workers()
        w_ref, _ = mpc_baseline.train(cfg, jax.random.PRNGKey(7), x, y,
                                      iters=iters)
        identical = bool((np.asarray(w) == np.asarray(w_ref)).all())
    trs = [t for r, t in sorted(runner.traces.items()) if r >= 1]
    waits = wait_summary([t.mpc_wait_s for t in trs])
    entry = {
        "wall_s_total": wall,
        "mpc_round": waits,
        "bit_identical": identical,
        "rounds": len(trs),
        "straggler_sleep_s": sleep_s,
        "T": cfg.T,
    }
    emit("socket/mpc_round", waits["mean"] * 1e6,
         f"BGW over TCP, straggler sleep {sleep_s}s, "
         f"bit_identical={identical}")
    return entry


def bench_scale_n(full: bool) -> dict:
    """Fleet-size trend: the same tiny problem on N=16/32/64 worker
    processes.  The N=64 point always runs (it is the one that catches
    O(N^2) wire or scheduler regressions) but on TRIMMED iterations so the
    default pass stays affordable on a contended box; ``--full`` restores
    the untrimmed count.  Per-round wall time grows with N (compute
    serializes across cores and the master writes N frames), so the gate is
    not a flat number but SANITY: every scale stays bit-identical and
    per-round overhead stays within an absolute ceiling — a superlinear
    blowup blows straight through it."""
    sizes = [16, 32, 64]
    points = []
    for n in sizes:
        iters = 4 if (n < 64 or full) else 3
        cfg = protocol.CPMLConfig(N=n, K=2, T=1, r=1)
        x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=256, d=32)
        entry = bench_socket(cfg, x, y, iters=iters, sleep_s=None,
                             connect_timeout_s=120.0 + 10.0 * n)
        points.append({
            "N": n,
            "threshold": cfg.threshold,
            "coded_T_mean_s": entry["coded_T"]["mean"],
            "full_round_mean_s": entry["full_round"]["mean"],
            "tx_bytes_per_round": entry["wire"]["tx_bytes_per_round"],
            "bit_identical": entry["bit_identical"],
        })
        emit(f"socket/scale_n[{n}]", entry["full_round"]["mean"] * 1e6,
             f"threshold={cfg.threshold} iters={iters} "
             f"bit_identical={entry['bit_identical']}")
    return {"points": points, "m": 256, "d": 32}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_socket.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + few rounds (CI)")
    ap.add_argument("--sleep-s", type=float, default=0.25,
                    help="injected straggler sleep per round (> 0)")
    ap.add_argument("--scale-n", action="store_true",
                    help="add the fleet-size trend (N=16/32/64 tiny-shape "
                         "runs; the N=64 point on trimmed iterations)")
    ap.add_argument("--full", action="store_true",
                    help="untrimmed iterations for the N=64 --scale-n point")
    args = ap.parse_args(argv)
    if args.sleep_s <= 0:
        ap.error("--sleep-s must be > 0: the straggler comparison is the "
                 "point of this benchmark")

    if args.smoke:
        n, k, m, d, iters = 5, 1, 128, 16, 5
    else:
        n, k, m, d, iters = 8, 2, 1024, 64, 12
    cfg = protocol.CPMLConfig(N=n, K=k, T=1, r=1)
    x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=m, d=d)

    inproc = bench_inprocess(cfg, x, y, iters)
    live = bench_socket(cfg, x, y, iters, sleep_s=None)
    # the same run on the legacy v1 wire: same messages, fatter frames —
    # the byte-for-byte baseline the packed/coalesced v2 format must beat
    live_v1 = bench_socket(cfg, x, y, iters, sleep_s=None, wire_version=1)
    straggled = bench_socket(cfg, x, y, iters, sleep_s=args.sleep_s)
    # the pipelined engine under the same real straggler: the stable fast
    # subset makes the streaming prediction hit, and the prefetch thread
    # hides the mask-row encode — compare the master-side (non-wait)
    # critical-path components, which is what pipelining shrinks
    straggled_pipe = bench_socket(cfg, x, y, iters, sleep_s=args.sleep_s,
                                  pipeline="full")
    # the same straggled run with the flight recorder on: spans recorded
    # master-side, worker spans shipped over the v2 TRACE field
    straggled_traced = bench_socket(cfg, x, y, iters, sleep_s=args.sleep_s,
                                    traced=True)
    # BGW head-to-head at its max honest-majority privacy T = (N-1)/2
    # (higher than the coded run's T — faithfully noted, paper §5)
    mpc_cfg = mpc_baseline.MPCConfig(N=n, T=(n - 1) // 2, r=1)
    mpc_iters = 4 if args.smoke else 8
    mpc_live = bench_socket_mpc(mpc_cfg, x, y, mpc_iters,
                                sleep_s=args.sleep_s)

    # like-for-like: both sides cover encode -> compute -> decode per round
    overhead = (live["full_round"]["mean"] - inproc["wall_s_per_round"])
    speedup_vs_mpc_live = (mpc_live["mpc_round"]["mean"]
                           / straggled["coded_T"]["mean"])
    wire_cmp = {
        "v1_tx_bytes_per_round": live_v1["wire"]["tx_bytes_per_round"],
        "v2_tx_bytes_per_round": live["wire"]["tx_bytes_per_round"],
        "v2_byte_ratio": (live["wire"]["tx_bytes_per_round"]
                          / max(live_v1["wire"]["tx_bytes_per_round"], 1.0)),
    }
    emit("socket/wire_v2_bytes", wire_cmp["v2_byte_ratio"] * 1e6,
         f"{wire_cmp['v2_tx_bytes_per_round'] / 1e3:.1f} kB/round vs "
         f"{wire_cmp['v1_tx_bytes_per_round'] / 1e3:.1f} kB v1")
    scale = bench_scale_n(args.full) if args.scale_n else None
    master_seq = (straggled["encode"]["mean"] + straggled["decode"]["mean"])
    master_pipe = (straggled_pipe["encode"]["mean"]
                   + straggled_pipe["decode"]["mean"])
    pipeline_cmp = {
        # per-round master-side (encode + decode) seconds on the critical
        # path — the wait itself is identical policy in both runs, so this
        # is the honest attribution of the pipelining effect on a wall
        # clock.  MEASUREMENT, not acceptance: these are ms-scale
        # components on a box running N worker processes, and swing 2-3x
        # between runs under CPU contention — the enforceable
        # pipelined-not-slower contract lives in bench_cluster.py's
        # simulated clock, where the comparison is deterministic.  The
        # acceptance here is structural: the pipeline machinery must have
        # actually engaged (every round prefetched, the streaming fold hit
        # at least once against the stable fast subset) and stayed
        # bit-identical.
        "sequential_master_s": master_seq,
        "pipelined_master_s": master_pipe,
        "master_speedup": master_seq / max(master_pipe, 1e-12),
        "streamed_rounds": straggled_pipe["streamed_rounds"],
        "prefetched_rounds": straggled_pipe["prefetched_rounds"],
    }
    trace_cmp = {
        # recorder-on vs recorder-off on the live wall clock.  The
        # full-round span is sleep-dominated (collect_all holds each round
        # open for the 0.25 s straggler), so its ratio is stable enough to
        # gate generously; the coded_T ratio is ms-scale under CPU
        # contention and is reported only (see the pipeline_cmp comment —
        # the tight ≤5% overhead gate is bench_cluster.py's, on the
        # simulated clock).
        "untraced_full_round_s": straggled["full_round"]["mean"],
        "traced_full_round_s": straggled_traced["full_round"]["mean"],
        "full_round_ratio": (straggled_traced["full_round"]["mean"]
                             / max(straggled["full_round"]["mean"], 1e-12)),
        "coded_T_ratio": (straggled_traced["coded_T"]["mean"]
                          / max(straggled["coded_T"]["mean"], 1e-12)),
        **straggled_traced["trace"],
    }
    emit("socket/trace_overhead", trace_cmp["full_round_ratio"] * 1e6,
         f"traced/untraced full-round ratio, "
         f"{trace_cmp['spans']} spans from "
         f"{trace_cmp['worker_processes']} worker process(es)")
    report = {
        "device": jax.default_backend(),
        "shapes": {"m": m, "d": d, "N": n, "K": k,
                   "threshold": cfg.threshold},
        "iters": iters,
        "smoke": args.smoke,
        "in_process": inproc,
        "socket": live,
        "socket_v1": live_v1,
        "socket_straggler": straggled,
        "socket_straggler_pipelined": straggled_pipe,
        "socket_straggler_traced": straggled_traced,
        "pipeline": pipeline_cmp,
        "trace_cmp": trace_cmp,
        "socket_mpc": mpc_live,
        "wire_cmp": wire_cmp,
        "scale_n": scale,
        "transport_overhead_s_per_round": overhead,
        "speedup_vs_mpc_live": speedup_vs_mpc_live,
        "acceptance": {
            # the paper's effect on a real wall clock: first-T strictly
            # below wait-all when a straggler process really sleeps
            "first_T_below_wait_all": bool(
                straggled["coded_T"]["mean"]
                < straggled["wait_all"]["mean"]),
            "bit_identical": bool(live["bit_identical"]
                                  and straggled["bit_identical"]),
            # the measured showdown: the same straggler that first-T decode
            # skips gates every BGW barrier, so MPC rounds cost strictly
            # more wall time than coded rounds
            "coded_below_measured_mpc": bool(speedup_vs_mpc_live > 1.0),
            "mpc_bit_identical": bool(mpc_live["bit_identical"]),
            # structural: the overlap machinery engaged on every round and
            # the incremental fold fired against the stable fast subset
            # (see pipeline_cmp comment for why the TIMING comparison is
            # reported but not gated on a live wall clock)
            "pipelined_engaged": bool(
                straggled_pipe["prefetched_rounds"]
                == straggled_pipe["rounds"]
                and straggled_pipe["streamed_rounds"] >= 1),
            "pipelined_bit_identical": bool(
                straggled_pipe["bit_identical"]),
            # wire v2 ships the same rounds in strictly fewer bytes than
            # the v1 baseline run (lossless narrowing + coalescing), and
            # the v1 run itself stays bit-identical — compatibility is
            # part of the contract, not just speed
            "wire_v2_fewer_bytes": bool(
                live["wire"]["tx_bytes_per_round"]
                < live_v1["wire"]["tx_bytes_per_round"]),
            "wire_v1_bit_identical": bool(live_v1["bit_identical"]),
            # flight recorder (DESIGN.md §11): tracing must not change the
            # training (bit-identity to the same oracle), every worker's
            # spans must land over the v2 TRACE field, per-round spans must
            # reconcile with wait_stats to float identity, no span left
            # open, and the sleep-dominated full-round time stays within a
            # generous bound of the untraced run (the tight ≤5% gate is
            # bench_cluster.py's, on the simulated clock)
            "trace_bit_identical": bool(straggled_traced["bit_identical"]),
            "trace_worker_spans_shipped": bool(
                straggled_traced["trace"]["worker_processes"] == n),
            "trace_reconciles_wait_stats": bool(
                straggled_traced["trace"]["reconciles"]
                and straggled_traced["trace"]["open_spans"] == 0),
            "trace_overhead_bounded": bool(
                trace_cmp["full_round_ratio"] <= 1.25),
        },
    }
    if not args.smoke:
        # ISSUE 6 acceptance: steady-state per-round first-T wait at the
        # committed full shapes (N=8, m=1024, d=64) at most half the
        # pre-v2 committed baseline's 0.516 s/round
        report["acceptance"]["round_overhead_halved"] = bool(
            live["coded_T"]["mean"] <= 0.26)
    if scale is not None:
        # sanity ceiling, not a race: see bench_scale_n docstring
        report["acceptance"]["scale_n_bit_identical"] = all(
            p["bit_identical"] for p in scale["points"])
        report["acceptance"]["scale_n_bounded"] = all(
            p["full_round_mean_s"] <= 2.0 for p in scale["points"])
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    ok = all(report["acceptance"].values())
    print(f"wrote {out}  acceptance={report['acceptance']}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
