"""Serving-plane benchmark: coded private inference under open-loop load.

Three questions about the prediction service (cluster/serve.py):

  1. THROUGHPUT CEILING — closed-loop clients (one full-batch query in
     flight at a time) on the simulated backend: how many queries/s and
     rows/s does a flush pipeline of encode -> N shares -> first-threshold
     decode sustain when the queue never goes idle?
  2. TAIL LATENCY UNDER A STRAGGLER — open-loop Poisson arrivals with one
     worker sleeping a fixed extra delay before every reply.  Two legs on
     the SAME arrival schedule: (A) the deployed first-threshold policy
     (each flush decoded at the fastest ``2(K+T-1)+1`` responders, the
     sleeper never on the critical path), and (B) the wait-for-all
     counterfactual (``collect_all`` keeps every flush open until the
     sleeper replies, so its delay lands on every query AND compounds
     through the queue).  The acceptance gate is the paper's serving
     claim: leg A's p99 stays bounded while leg B's p99 absorbs the
     straggler — ``p99(A, first-threshold) < p99(B, wait-all)``.
  3. LIVE BIT-IDENTITY — the same two legs over real TCP worker processes
     (launch/cpml_worker.py in its ``serve`` protocol mode) with a worker
     that REALLY sleeps: served predictions must be bit-identical to the
     uncoded plaintext oracle on both backends and both legs.  A fast
     wrong answer is worthless; exact interpolation of the quantized
     product is the contract (DESIGN.md §12).

    PYTHONPATH=src python benchmarks/bench_serve.py [--smoke] [--out PATH]

Writes BENCH_serve.json; CI runs --smoke and uploads the artifact
alongside BENCH_protocol.json / BENCH_socket.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from common import emit

from repro.cluster import DeterministicLatency
from repro.cluster.latency import SleepyStragglerLatency
from repro.cluster.serve import (PredictionServer, ServeConfig,
                                 open_loop_queries)
from repro.launch.cpml_cluster import local_socket_cluster


def _weights(d: int, classes: int):
    return 0.5 * jax.random.normal(jax.random.PRNGKey(11), (d, classes))


def _entry(srv: PredictionServer, wall_s: float | None = None) -> dict:
    s = srv.stats()
    return {
        "queries": s["queries"],
        "rejected": s["rejected"],
        "rounds": s["rounds"],
        "queries_per_s": s["queries_per_s"],
        "rows_per_s": s["rows_per_s"],
        "lat_first": s["latency_first"],
        "lat_all": s["latency_all"],
        "bit_identical": bool(s["oracle"]["bit_identical"]
                              and s["oracle"]["checked"]),
        "oracle_flushes": s["oracle"]["checked"],
        "wall_s": wall_s,
    }


def bench_sim_closed(cfg: ServeConfig, d: int, classes: int,
                     n_queries: int) -> dict:
    """Throughput ceiling: saturated full-batch queries, no arrival gaps."""
    srv = PredictionServer(cfg, _weights(d, classes), jax.random.PRNGKey(3),
                           latency=DeterministicLatency(base=1e-3, skew=0.1),
                           verify=True)
    qs = open_loop_queries(n_queries, rows=cfg.max_batch, d=d,
                           rate_qps=0.0, seed=5)
    srv.run_closed_loop(qs)
    e = _entry(srv)
    emit("serve/sim_closed_qps", 1e6 / max(e["queries_per_s"], 1e-9),
         f"{e['queries_per_s']:.1f} queries/s, {e['rows_per_s']:.0f} rows/s "
         f"(simulated), bit_identical={e['bit_identical']}")
    return e


def bench_sim_straggler(cfg: ServeConfig, d: int, classes: int,
                        n_queries: int, rows: int, rate_qps: float,
                        sleep_s: float) -> dict:
    """Legs A/B of question 2 on the simulated clock: identical arrivals,
    identical latency draws, only the wait policy differs.  Straggler
    exclusion is OFF in both legs so every flush dispatches to all N and
    the comparison isolates decode-at-threshold vs wait-for-all."""
    legs = {}
    for name, collect_all in (("first_threshold", False), ("wait_all", True)):
        lat = SleepyStragglerLatency(
            DeterministicLatency(base=1e-3, skew=0.1),
            {cfg.N - 1: sleep_s})
        srv = PredictionServer(cfg, _weights(d, classes),
                               jax.random.PRNGKey(3), latency=lat,
                               collect_all=collect_all,
                               exclude_stragglers=False, verify=True)
        srv.run(open_loop_queries(n_queries, rows=rows, d=d,
                                  rate_qps=rate_qps, seed=5))
        legs[name] = _entry(srv)
    a, b = legs["first_threshold"], legs["wait_all"]
    emit("serve/sim_straggler_p99", a["lat_first"]["p99"] * 1e6,
         f"first-T p99 vs wait-all p99 {b['lat_all']['p99']:.3f}s "
         f"(sleep {sleep_s}s)")
    return {"sleep_s": sleep_s, "rate_qps": rate_qps, **{
        "first_threshold": a, "wait_all": b}}


def bench_socket_straggler(cfg: ServeConfig, d: int, classes: int,
                           n_queries: int, rows: int, rate_qps: float,
                           sleep_s: float) -> dict:
    """The same two legs over real TCP worker processes: the straggler
    process really time.sleep()s before each reply."""
    legs = {}
    for name, collect_all in (("first_threshold", False), ("wait_all", True)):
        with local_socket_cluster(cfg.N,
                                  sleep_s={cfg.N - 1: sleep_s}) as tr:
            srv = PredictionServer(cfg, _weights(d, classes),
                                   jax.random.PRNGKey(3), transport=tr,
                                   round_timeout_s=300.0,
                                   collect_all=collect_all,
                                   exclude_stragglers=False, verify=True)
            srv.provision()
            t0 = time.perf_counter()
            srv.run(open_loop_queries(n_queries, rows=rows, d=d,
                                      rate_qps=rate_qps, seed=5))
            wall = time.perf_counter() - t0
            srv.shutdown_workers()
        legs[name] = _entry(srv, wall_s=wall)
    a, b = legs["first_threshold"], legs["wait_all"]
    emit("serve/socket_straggler_p99", a["lat_first"]["p99"] * 1e6,
         f"first-T p99 vs wait-all p99 {b['lat_all']['p99']:.3f}s "
         f"over TCP (sleep {sleep_s}s), "
         f"bit_identical={a['bit_identical'] and b['bit_identical']}")
    return {"sleep_s": sleep_s, "rate_qps": rate_qps, **{
        "first_threshold": a, "wait_all": b}}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + few queries (CI)")
    ap.add_argument("--sleep-s", type=float, default=0.3,
                    help="injected straggler sleep per flush (> 0)")
    args = ap.parse_args(argv)
    if args.sleep_s <= 0:
        ap.error("--sleep-s must be > 0: the straggler comparison is the "
                 "point of this benchmark")

    if args.smoke:
        n, k, t = 6, 2, 1
        d, classes = 16, 6
        max_batch, rows = 8, 4
        n_queries, sock_queries, rate = 24, 16, 150.0
    else:
        n, k, t = 8, 2, 1
        d, classes = 64, 10
        max_batch, rows = 32, 4
        n_queries, sock_queries, rate = 96, 32, 400.0
    cfg = ServeConfig(N=n, K=k, T=t, max_batch=max_batch, max_wait_s=0.02)

    closed = bench_sim_closed(cfg, d, classes, n_queries=n_queries)
    sim = bench_sim_straggler(cfg, d, classes, n_queries=n_queries,
                              rows=rows, rate_qps=rate,
                              sleep_s=args.sleep_s)
    sock = bench_socket_straggler(cfg, d, classes, n_queries=sock_queries,
                                  rows=rows, rate_qps=rate,
                                  sleep_s=args.sleep_s)

    report = {
        "device": jax.default_backend(),
        "shapes": {"N": n, "K": k, "T": t, "threshold": cfg.threshold,
                   "d": d, "classes": classes, "max_batch": max_batch,
                   "rows_per_query": rows},
        "smoke": args.smoke,
        "straggler_sleep_s": args.sleep_s,
        "sim_closed_loop": closed,
        "sim_open_loop_straggler": sim,
        "socket_open_loop_straggler": sock,
        "acceptance": {
            # the serving claim: under the same straggled open-loop load,
            # first-threshold decode keeps p99 bounded while wait-for-all
            # absorbs the sleeper's delay on every query
            "sim_p99_first_below_wait_all": bool(
                sim["first_threshold"]["lat_first"]["p99"]
                < sim["wait_all"]["lat_all"]["p99"]),
            "socket_p99_first_below_wait_all": bool(
                sock["first_threshold"]["lat_first"]["p99"]
                < sock["wait_all"]["lat_all"]["p99"]),
            # exact interpolation of the quantized product — every flush,
            # every leg, both backends
            "sim_bit_identical": bool(
                closed["bit_identical"]
                and sim["first_threshold"]["bit_identical"]
                and sim["wait_all"]["bit_identical"]),
            "socket_bit_identical": bool(
                sock["first_threshold"]["bit_identical"]
                and sock["wait_all"]["bit_identical"]),
            # the bounded queue never rejected: the load is sized so the
            # first-threshold service keeps up with the offered rate
            "sim_no_rejections": bool(
                sim["first_threshold"]["rejected"] == 0),
        },
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    ok = all(report["acceptance"].values())
    print(f"wrote {out}  acceptance={report['acceptance']}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
