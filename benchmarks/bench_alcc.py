"""ALCC float-engine benchmark: speed parity + convergence gates.

Three acceptance gates, all of which FAIL the job (nonzero exit) when
violated — CI runs ``--smoke`` on every push (see .github/workflows/ci.yml):

  * SPEED PARITY — the ALCC engine's per-round wall time through the same
    ClusterRunner + EventScheduler path must be <= 1.25x the exact
    finite-field engine at EQUAL shapes (same N/K/T/r, same data, same
    deterministic latency model).  ALCC trades the quantize/field-reduce
    work of the exact engine for float64 Vandermonde solves at decode; the
    gate pins down that this trade stays within noise of parity.
  * LOGISTIC CONVERGENCE — ALCC coded training (train_reference over the
    same hooks the runner drives) must land within ``W_TOL`` max|dw| of the
    UNCODED float oracle (same surrogate, same batches, same step sizes),
    i.e. the masks cancel and decode noise stays at float-roundoff scale.
  * MLP CONVERGENCE — the two-phase coded MLP (cluster/alcc_mlp.py) must
    reach within ``ALCC_MLP_LOSS_TOL`` of the plaintext jax.grad oracle's
    final full-data loss.  The tolerance is on LOSS, not weights: at long
    horizons SGD chaotically amplifies f32 roundoff into weight drift that
    is sigma-independent, while the loss surface it lands on is the same
    (DESIGN.md §14).

    PYTHONPATH=src python benchmarks/bench_alcc.py [--smoke] [--out PATH]

Writes BENCH_alcc.json and uploads it as a CI artifact.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from common import emit

from repro.cluster import ClusterRunner, make_latency
from repro.cluster.alcc_mlp import ALCCMLPRunner
from repro.core.protocol import alcc_engine
from repro.core.protocol.config import CPMLConfig
from repro.data import synthetic
from repro.launch.cpml_cluster import ALCC_MLP_LOSS_TOL

SPEED_RATIO_LIMIT = 1.25   # ALCC per-round <= 1.25x exact (ISSUE acceptance)
W_TOL = 1e-3               # logistic max|w_alcc - w_oracle| ceiling
N_WORKERS = 8


def _time_run(make_runner, iters: int, repeats: int = 3) -> float:
    """Median wall-microseconds per round.  One throwaway run first so jit
    compilation (shared per-process cache) is off the clock."""
    make_runner().run(max(2, iters // 4))
    times = []
    for _ in range(repeats):
        runner = make_runner()
        t0 = time.perf_counter()
        runner.run(iters)
        times.append((time.perf_counter() - t0) / iters)
    times.sort()
    return times[len(times) // 2] * 1e6


def bench_speed(m: int, d: int, iters: int) -> dict:
    x, y = synthetic.mnist_like(jax.random.PRNGKey(0), m=m, d=d)
    lat = lambda: make_latency("deterministic", seed=11)
    exact_cfg = CPMLConfig(N=N_WORKERS, K=2, T=1, r=1)
    alcc_cfg = alcc_engine.ALCCConfig(N=N_WORKERS, K=2, T=1, r=1, sigma=1.0)
    exact_us = _time_run(
        lambda: ClusterRunner(exact_cfg, jax.random.PRNGKey(7), x, y, lat()),
        iters)
    alcc_us = _time_run(
        lambda: ClusterRunner(alcc_cfg, jax.random.PRNGKey(7), x, y, lat(),
                              engine="alcc"),
        iters)
    ratio = alcc_us / exact_us
    ok = ratio <= SPEED_RATIO_LIMIT
    emit("alcc_round", alcc_us, f"ratio_vs_exact={ratio:.3f}")
    emit("exact_round", exact_us, "")
    return {
        "exact_round_us": exact_us,
        "alcc_round_us": alcc_us,
        "ratio": ratio,
        "limit": SPEED_RATIO_LIMIT,
        "ok": bool(ok),
    }


def bench_logistic(m: int, d: int, iters: int) -> dict:
    cfg = alcc_engine.ALCCConfig(N=N_WORKERS, K=2, T=1, r=1, sigma=1.0)
    key = jax.random.PRNGKey(3)
    x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=m, d=d)
    w, _ = alcc_engine.train_reference(cfg, key, x, y, iters)
    w_o = alcc_engine.float_oracle(cfg, key, x, y, iters)
    gap = float(np.max(np.abs(np.asarray(w) - np.asarray(w_o))))
    _, acc = alcc_engine.loss_and_accuracy(w, x, y)
    _, acc_o = alcc_engine.loss_and_accuracy(w_o, x, y)
    ok = gap <= W_TOL
    emit("alcc_logistic", 0.0, f"max_dw_vs_oracle={gap:.2e}")
    return {
        "max_dw_vs_oracle": gap,
        "tol": W_TOL,
        "acc_alcc": float(acc),
        "acc_oracle": float(acc_o),
        "ok": bool(ok),
    }


def bench_mlp(m: int, d: int, c: int, hidden: int, iters: int, eta: float
              ) -> dict:
    cfg = alcc_engine.ALCCConfig(N=N_WORKERS, K=2, T=1, r=1, c=c, sigma=1.0,
                                 batch_rows=None)
    key = jax.random.PRNGKey(5)
    x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(2), m=m, d=d,
                                           c=c)
    runner = ALCCMLPRunner(cfg, key, x, y, hidden,
                           make_latency("deterministic", seed=13), eta=eta)
    t0 = time.perf_counter()
    w1, w2 = runner.run(iters)
    per_step_us = (time.perf_counter() - t0) / iters * 1e6
    loss, acc = runner.metrics_now()
    w1_o, w2_o = alcc_engine.mlp_oracle(cfg, key, x, y, hidden, iters, eta)
    loss_o, acc_o = alcc_engine.mlp_metrics(runner.state, w1_o, w2_o)
    gap = abs(loss - loss_o)
    ok = gap <= ALCC_MLP_LOSS_TOL
    emit("alcc_mlp_step", per_step_us, f"dloss_vs_oracle={gap:.2e}")
    return {
        "loss_coded": loss,
        "acc_coded": acc,
        "loss_oracle": loss_o,
        "acc_oracle": acc_o,
        "loss_gap": gap,
        "tol": ALCC_MLP_LOSS_TOL,
        "per_step_us": per_step_us,
        "decode": runner.wait_stats().get("alcc", {}),
        "ok": bool(ok),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI (same gates)")
    ap.add_argument("--out", default="BENCH_alcc.json")
    args = ap.parse_args()

    if args.smoke:
        shapes = dict(m=512, d=16, iters=10, log_iters=25,
                      mlp=dict(m=384, d=16, c=4, hidden=16, iters=12,
                               eta=0.1))
    else:
        shapes = dict(m=4096, d=64, iters=30, log_iters=60,
                      mlp=dict(m=1024, d=32, c=4, hidden=32, iters=40,
                               eta=0.1))

    out = {
        "smoke": bool(args.smoke),
        "shapes": shapes,
        "speed": bench_speed(shapes["m"], shapes["d"], shapes["iters"]),
        "logistic": bench_logistic(shapes["m"], shapes["d"],
                                   shapes["log_iters"]),
        "mlp": bench_mlp(**shapes["mlp"]),
    }
    out["ok"] = bool(out["speed"]["ok"] and out["logistic"]["ok"]
                     and out["mlp"]["ok"])
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}  ok={out['ok']} "
          f"(speed ratio {out['speed']['ratio']:.3f} <= "
          f"{SPEED_RATIO_LIMIT}, logistic dw {out['logistic']['max_dw_vs_oracle']:.2e}, "
          f"mlp dloss {out['mlp']['loss_gap']:.2e})")
    return 0 if out["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
