"""Generate EXPERIMENTS.md from dry-run results + hillclimb records.

    PYTHONPATH=src:. python -m benchmarks.make_experiments \
        --results benchmarks/results_final --fallback benchmarks/results_v2
"""
import argparse
import glob
import json
import os

HW = ("TPU v5e target: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI "
      "per chip; 256 chips/pod")


def load_cells(primary, fallback):
    cells = {}
    for d in (fallback, primary):
        if not d:
            continue
        for p in sorted(glob.glob(os.path.join(d, "dryrun_*__16x16.json"))):
            c = json.load(open(p))
            cells[(c["arch"], c["shape"])] = c
    return cells


def load_multipod(d):
    out = {}
    for p in sorted(glob.glob(os.path.join(d, "dryrun_*__2x16x16.json"))):
        c = json.load(open(p))
        out[(c["arch"], c["shape"])] = c
    return out


def fmt_bytes(x):
    return f"{x/1e9:.1f}G" if x < 1e12 else f"{x/1e12:.2f}T"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="benchmarks/results_final")
    ap.add_argument("--fallback", default="benchmarks/results_v2")
    ap.add_argument("--multipod", default="benchmarks/results")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    args = ap.parse_args()

    cells = load_cells(args.results, args.fallback)
    mp = load_multipod(args.multipod)

    L = []
    A = L.append
    A("# EXPERIMENTS\n")
    A(f"Hardware model: {HW}.\n")

    # ------------------------------------------------------------ paper
    A("## §Paper-validation (faithful reproduction)\n")
    A("Measured on this container (1 CPU core; `benchmarks/run.py`, reduced"
      " scale m=1200,d=128; `--full` reproduces the paper's 12396x1568):\n")
    A("| metric | paper | this repo |")
    A("|---|---|---|")
    A("| CPML vs MPC speedup, N=10 | ~3.3x (Table 2) | 4.3x |")
    A("| CPML vs MPC speedup, N=25 | ~12.6x (Table 3) | 27.1x (CPU-core-"
      "bound MPC comm) |")
    A("| speedup growth with N | increasing (Fig. 2) | 4.3x -> 27.1x |")
    A("| MPC comm blow-up with N | Tables 2-3 | 24.9s -> 84.8s |")
    A("| accuracy vs uncoded logreg, 25 iters | 95.04% vs 95.98% (Fig. 3) |"
      " 82.25% vs 82.62% (harder synthetic task; gap 0.4pt matches) |")
    A("| convergence curves | overlapping (Fig. 4) | overlapping "
      "(fig4_* rows in bench_output.txt) |")
    A("| recovery threshold (2r+1)(K+T-1)+1 | Thm. 1 | enforced + tested "
      "(any threshold-sized survivor subset decodes identically) |")
    A("| T-collusion privacy | Eq. 4 / A.4 | MDS-submatrix + uniform-share "
      "tests (tests/test_lagrange.py) |")
    A("")
    A("Fidelity deviations (DESIGN.md §6): explicit sigmoid-coefficient "
      "scale lc (the paper's implicit lc=0 rounds the fitted slope to ZERO "
      "— tests/test_sigmoid_poly.py documents it), per-part decode for "
      "headroom, P30 extended prime for r=2 (24-bit prime wraps; "
      "headroom_bits() guards), erasure-mask straggler semantics.\n")

    # ------------------------------------------------------------ dryrun
    A("## §Dry-run\n")
    n_ok = sum(c["status"] == "ok" for c in cells.values())
    n_skip = sum(c["status"] == "skipped" for c in cells.values())
    mp_ok = sum(c["status"] == "ok" for c in mp.values())
    mp_skip = sum(c["status"] == "skipped" for c in mp.values())
    A(f"Single-pod 16x16 (256 chips): **{n_ok} ok / {n_skip} skipped / 0 "
      f"errors**.  Multi-pod 2x16x16 (512 chips): **{mp_ok} ok / {mp_skip} "
      "skipped / 0 errors** — every (arch x shape) cell lowers AND compiles "
      "with the `pod` axis sharded (proves DCN-crossing data parallelism "
      "partitions).  Skips are the 7 full-attention long_500k cells "
      "(DESIGN.md §4).  Per-cell JSON: benchmarks/results*/.\n")
    A("Per-device memory (train_4k cells, single-pod).  `args` is the "
      "sharded params+optimizer+batch footprint from memory_analysis(); "
      "`xla-cpu temp` is the CPU backend's scratch — it keeps f32 copies "
      "and skips the TPU memory-optimization passes, so the TPU-relevant "
      "check is `analytic`: FSDPxTP-sharded params (bf16) + AdamW state "
      "(f32 m,v) per device, + remat'd activations (~1-2G at these "
      "shapes):\n")
    A("| arch | args | xla-cpu temp | analytic params+opt/device | fits "
      "16GB v5e? |")
    A("|---|---|---|---|---|")
    import sys
    sys.path.insert(0, "src")
    from repro.configs import registry as _reg
    for (arch, shape), c in sorted(cells.items()):
        if shape != "train_4k" or c["status"] != "ok":
            continue
        m = c["memory"]
        args_b, temp_b = m["argument_size_in_bytes"], m["temp_size_in_bytes"]
        n = _reg.get_config(arch).param_count()
        analytic = n * (2 + 8) / 256    # bf16 params + f32 m,v — fully sharded
        fits = "yes" if analytic + 2e9 < 16e9 else "NO"
        A(f"| {arch} | {fmt_bytes(args_b)} | {fmt_bytes(temp_b)} | "
          f"{fmt_bytes(analytic)} | {fits} |")
    A("")

    # ------------------------------------------------------------ roofline
    A("## §Roofline (single-pod, per optimizer/serve step)\n")
    A("Terms from the compiled HLO via the trip-count-aware analyzer "
      "(launch/hlo_analysis.py): dot-exact FLOPs; bytes charged at fusion "
      "boundaries with in-place DUS/slice discounts; collective bytes = "
      "post-SPMD shard sizes (all-reduce 2x).  XLA's own cost_analysis "
      "undercounts scan bodies ~L-fold (counted once) — both are recorded "
      "per cell.\n")
    A("| arch | shape | compute_s | memory_s | collective_s | dominant | "
      "6ND/HLO | roofline frac |")
    A("|---|---|---|---|---|---|---|---|")
    rows = []
    for (arch, shape), c in sorted(cells.items()):
        if c["status"] == "skipped":
            A(f"| {arch} | {shape} | — | — | — | skipped (full-attn "
              "long-context) | — | — |")
            continue
        t = c["roofline_terms_s"]
        frac = t["compute_s"] / c["step_time_bound_s"]
        rows.append((frac, arch, shape, c))
        A(f"| {arch} | {shape} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
          f"| {t['collective_s']:.3f} | {c['dominant'].replace('_s','')} | "
          f"{c['useful_ratio']:.2f} | {100*frac:.1f}% |")
    A("")
    A("One-line bottleneck reads (what would move the dominant term):\n")
    notes = {
        ("memory_s", "train"): "activation/score traffic -> sequence "
            "parallelism (proven -76% on mistral) + flash-attention kernel",
        ("memory_s", "prefill"): "score-tile/scan traffic -> fused kernels "
            "(mamba_scan.py) + bf16 streaming",
        ("memory_s", "decode"): "KV-cache reads are the step: already at "
            "the cache-streaming bound; quantized (int8) cache next",
        ("collective_s", "train"): "gradient all-reduce + FSDP gathers -> "
            "overlap with backward, gradient compression (optim/compress)",
        ("collective_s", "prefill"): "MoE all-to-alls + FSDP gathers -> "
            "smaller dispatch groups (proven -82% on arctic), EP-major mesh",
        ("collective_s", "decode"): "per-token weight gathers -> "
            "weight-stationary inference sharding profile",
    }
    seen = set()
    for frac, arch, shape, c in sorted(rows)[:12]:
        kind = "train" if "train" in shape else (
            "prefill" if "prefill" in shape else "decode")
        k = (c["dominant"], kind)
        if k in seen:
            continue
        seen.add(k)
        A(f"* **{arch} x {shape}** ({c['dominant']}): {notes.get(k, '')}")
    A("")

    # ------------------------------------------------------------ perf
    A("## §Perf — hillclimb log (hypothesis -> change -> measure)\n")
    A("Three cells per the brief (worst roofline fraction, most collective-"
      "bound, most representative) + the paper's own technique.  Full "
      "records: benchmarks/results*/hillclimb_*.json.\n")
    A(open(os.path.join(os.path.dirname(__file__),
                        "perf_log.md")).read() if os.path.exists(
        os.path.join(os.path.dirname(__file__), "perf_log.md")) else "")
    with open(args.out, "w") as f:
        f.write("\n".join(L))
    print(f"wrote {args.out}: {len(cells)} cells "
          f"({n_ok} ok, {n_skip} skipped), multipod {len(mp)}")


if __name__ == "__main__":
    main()
