"""Shared benchmark plumbing: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows; `derived` carries
the benchmark-specific figure of merit (speedup, accuracy, roofline term...).
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
