"""Phase-level timing of CPML vs the MPC baseline (paper Tables 1-6 axes).

Phases (matching the paper's breakdown):
  encode — dataset + per-round weight secret sharing
  comm   — master<->worker + worker<->worker movement: CPML = result gather
           + decode matmul; MPC = per-multiplication reshare (all-to-all) +
           reconstruction
  comp   — the workers' polynomial evaluations

The default scale is reduced (CPU container); --full uses the paper's
(m, d) = (12396, 1568).  Structure, not absolute seconds, is the claim
being reproduced: CPML's encode ~1/K dataset per worker, zero worker<->worker
rounds; MPC's full replication + per-mul communication.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field, lagrange, mpc_baseline as mpc, protocol, \
    quantize, sigmoid_poly
from repro.data import synthetic


def _t(fn, *a):
    t0 = time.perf_counter()
    out = fn(*a)
    jax.block_until_ready(out)
    return time.perf_counter() - t0, out


def cpml_phase_times(cfg: protocol.CPMLConfig, x, y, iters: int = 5) -> dict:
    key = jax.random.PRNGKey(0)
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(
        cfg.r, cfg.lx, cfg.lw, cfg.lc, cfg.p), jnp.int32)
    t_enc_data, (shares, _) = _t(
        functools.partial(protocol.encode_dataset, cfg, key), x)
    w = jnp.zeros(x.shape[1])
    enc_w = jax.jit(lambda k, w: protocol.encode_weights(cfg, k, w))
    workers = jax.jit(lambda xs, ws: protocol.all_worker_results(
        cfg, cbar, xs, ws))
    dmat = protocol.make_decode_matrix(cfg, np.arange(cfg.threshold))
    dec = jax.jit(lambda r: protocol.decode_gradient(cfg, r, dmat))
    t_enc = t_comp = t_comm = 0.0
    for i in range(iters):
        k = jax.random.fold_in(key, i)
        dt, w_shares = _t(enc_w, k, w)
        t_enc += dt
        dt, results = _t(workers, shares, w_shares)
        t_comp += dt
        dt, _ = _t(dec, results[: cfg.threshold])
        t_comm += dt
    return {"encode": t_enc_data + t_enc, "comm": t_comm, "comp": t_comp,
            "total": t_enc_data + t_enc + t_comm + t_comp}


def mpc_phase_times(cfg: mpc.MPCConfig, x, y, iters: int = 5) -> dict:
    key = jax.random.PRNGKey(0)
    xq = quantize.quantize_data(x, cfg.lx, cfg.p)
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(
        cfg.r, cfg.lx, cfg.lw, cfg.lc, cfg.p), jnp.int32)
    t_enc_data, x_shares = _t(jax.jit(
        lambda k, v: mpc.share(cfg, k, v)), key, xq)
    w = jnp.zeros(x.shape[1])

    @jax.jit
    def enc_w(k, w):
        wbar = quantize.quantize_weights(k, w, cfg.lw, cfg.r, cfg.p)
        return mpc.share(cfg, k, wbar)

    @jax.jit
    def local_mul1(xs, ws):           # Z = X̄ w̄ per worker (degree 2T)
        return jax.vmap(lambda a, b: field.matmul(a, b, cfg.p))(xs, ws)

    @jax.jit
    def reshare(k, z):                # the communication round
        return mpc.degree_reduce(cfg, k, z)

    @jax.jit
    def local_mul2(xs, z):            # s then X̄ᵀ s per worker
        prod = z[..., 0]
        s = field.addmod(jnp.broadcast_to(cbar[0], prod.shape),
                         field.mulmod(jnp.broadcast_to(cbar[1], prod.shape),
                                      prod, cfg.p), cfg.p)
        return jax.vmap(lambda a, b: field.matmul(a.T, b[:, None], cfg.p)
                        [:, 0])(xs, s)

    @jax.jit
    def reconstruct(g):
        return mpc.reconstruct(cfg, g, 2 * cfg.T)

    t_enc = t_comp = t_comm = 0.0
    for i in range(iters):
        k = jax.random.fold_in(key, i)
        dt, w_shares = _t(enc_w, k, w)
        t_enc += dt
        dt, z = _t(local_mul1, x_shares, w_shares)
        t_comp += dt
        dt, z = _t(reshare, k, z)
        t_comm += dt
        dt, g = _t(local_mul2, x_shares, z)
        t_comp += dt
        dt, _ = _t(reconstruct, g)
        t_comm += dt
    return {"encode": t_enc_data + t_enc, "comm": t_comm, "comp": t_comp,
            "total": t_enc_data + t_enc + t_comm + t_comp}


def case1(N: int, r: int = 1) -> protocol.CPMLConfig:
    """Paper Case 1: maximum parallelization, K = (N-1)/(2r+1), T=1."""
    K = max(1, (N - 1) // (2 * r + 1))
    return protocol.CPMLConfig(N=N, K=K, T=1, r=r)


def case2(N: int, r: int = 1) -> protocol.CPMLConfig:
    """Paper Case 2: equal parallelization and privacy, K = T = (N+2)/6."""
    K = T = max(1, (N + 2) // (2 * (2 * r + 1)))
    return protocol.CPMLConfig(N=N, K=K, T=T, r=r)
