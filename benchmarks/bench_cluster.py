"""Cluster-runtime benchmark: first-T-responders vs wait-for-all vs MPC.

Reproduces the paper's core systems result (Fig. 5) in simulation: per-round
completion time when the master decodes at the fastest ``threshold``
responders, versus waiting for every worker, versus the BGW MPC baseline —
which not only waits for everyone but pays ``r + 1`` all-to-all
communication rounds per iteration (one per degree reduction plus the
reconstruction), each gated on the SLOWEST worker.  All three policies are
driven by the same seeded latency models (repro.cluster.latency), so the
comparison isolates protocol structure from noise.

``speedup_vs_mpc`` is MEASURED: the BGW baseline actually runs through the
cluster runtime (cluster/mpc_runner.py — multi-phase rounds, reshare
barriers, reconstruction at the first 2T+1 arrivals, bit-identity to the
single-host oracle enforced by tests) under the same latency models.  The
pre-PR-4 analytic counterfactual (r+1 closed-form max-over-workers terms)
is preserved under each model's ``modeled`` key so the bench trajectory is
not silently redefined.

PIPELINED vs SEQUENTIAL (DESIGN.md §9): the same latency models drive the
round engine with ``--pipeline off`` vs ``full`` under modeled master-side
encode/decode costs charged to the simulated clock.  Latency samples are
(round, worker)-keyed and order-independent, so both runs observe the SAME
responder traces and produce bit-identical weights — the comparison
isolates exactly the critical-path time pipelining removes (the mask-row
encode fraction and all but one decode fold).  Acceptance requires
pipelined <= sequential per-round critical path under lognormal and bursty.

Also times the on-device compute of one coded round vs one MPC step (same
data, same quantization) for the device-side of the story.

    PYTHONPATH=src python benchmarks/bench_cluster.py [--smoke] [--out PATH]

Writes BENCH_cluster.json; CI runs --smoke on every push (satellite: the
runtime path is exercised continuously) and uploads the JSON artifact.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from common import emit, time_fn

from repro.cluster import (
    ClusterRunner,
    MPCClusterRunner,
    make_latency,
    mpc_phase_models,
    wait_summary,
)
from repro.core import mpc_baseline, protocol
from repro.data import synthetic

N_WORKERS = 8
MODELS = ("deterministic", "lognormal", "bursty")
# modeled master-side coding costs charged to the simulated clock for the
# pipelined-vs-sequential comparison (a realistic fraction of the ~1s mean
# worker latency the models draw; the WAIT component is identical between
# modes, so any positive cost isolates the pipelining effect)
ENCODE_COST_S = 0.2
DECODE_COST_S = 0.1


def simulate_mpc_waits(name: str, seed: int, iters: int, r: int
                       ) -> np.ndarray:
    """The RETAINED analytic BGW wait model (reported under ``modeled``).

    r + 1 sequential all-to-all rounds per iteration, each gated on the
    slowest of ALL N workers.  Noise pairing is BY CONSTRUCTION identical
    to the measured run: the phase models come from the same
    mpc_phase_models factory.  The measured number differs structurally in
    one place: the analytic final term is max-over-all-N, while the real
    master reconstructs at the (2T+1)-th arrival of the final shares."""
    comm = mpc_phase_models(name, seed=seed, r=r)
    waits = np.empty(iters)
    for t in range(iters):
        waits[t] = sum(max(model.sample(t, w) for w in range(N_WORKERS))
                       for model in comm)
    return waits


def bench_model(name: str, cfg, mpc_cfg, x, y, iters: int, seed: int
                ) -> dict:
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                           make_latency(name, seed=seed))
    runner.run(iters)
    stats = runner.wait_stats()              # inf-filters dead rounds
    # MEASURED: the BGW protocol itself through the same runtime + models
    bgw = MPCClusterRunner(mpc_cfg, jax.random.PRNGKey(7), x, y,
                           mpc_phase_models(name, seed=seed, r=mpc_cfg.r))
    bgw.run(iters)
    measured = np.array([tr.mpc_wait_s
                         for tr in sorted(bgw.traces.values(),
                                          key=lambda t: t.round)])
    modeled = simulate_mpc_waits(name, seed, iters, mpc_cfg.r)
    coded_mean = stats["coded_T"]["mean"]
    entry = {
        "coded_T": stats["coded_T"],
        "wait_all": stats["wait_all"],
        "rounds": stats["rounds"],
        "mpc": wait_summary(measured),
        "speedup_vs_wait_all": float(stats["wait_all"]["mean"]
                                     / coded_mean),
        "speedup_vs_mpc": float(measured.mean() / coded_mean),
        "modeled": {
            "mpc": wait_summary(modeled),
            "speedup_vs_mpc": float(modeled.mean() / coded_mean),
        },
    }
    emit(f"cluster_round/{name}/coded_T", coded_mean * 1e6,
         f"vs wait_all {stats['wait_all']['mean']:.3f}s "
         f"({entry['speedup_vs_wait_all']:.2f}x), "
         f"vs mpc {measured.mean():.3f}s measured "
         f"({entry['speedup_vs_mpc']:.2f}x; modeled "
         f"{entry['modeled']['speedup_vs_mpc']:.2f}x)")
    return entry


def bench_pipeline(name: str, cfg, x, y, iters: int, seed: int) -> dict:
    """Pipelined vs sequential per-round critical path under one latency
    model (DESIGN.md §9).  Order-independent latency sampling makes the
    responder traces — and therefore the weights — identical between
    modes; only the master-side encode/decode charges differ."""
    runs: dict[str, dict] = {}
    weights: dict[str, np.ndarray] = {}
    for mode in ("off", "full"):
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                               make_latency(name, seed=seed),
                               pipeline=mode,
                               encode_cost_s=ENCODE_COST_S,
                               decode_cost_s=DECODE_COST_S)
        weights[mode] = np.asarray(runner.run(iters))
        stats = runner.wait_stats()
        runs[mode] = {"critical_path": stats["critical_path"],
                      "encode": stats["encode"],
                      "decode": stats["decode"],
                      "streamed_rounds": stats["rounds"]["streamed"],
                      "prefetched_rounds": stats["rounds"]["prefetched"]}
    speedup = (runs["off"]["critical_path"]["mean"]
               / runs["full"]["critical_path"]["mean"])
    entry = {
        "sequential": runs["off"],
        "pipelined": runs["full"],
        "encode_cost_s": ENCODE_COST_S,
        "decode_cost_s": DECODE_COST_S,
        "critical_path_speedup": float(speedup),
        "bit_identical_modes": bool((weights["off"]
                                     == weights["full"]).all()),
    }
    emit(f"cluster_pipeline/{name}/critical_path",
         runs["full"]["critical_path"]["mean"] * 1e6,
         f"vs sequential {runs['off']['critical_path']['mean']:.3f}s "
         f"({speedup:.3f}x, bit_identical="
         f"{entry['bit_identical_modes']})")
    return entry


def bench_trace_overhead(cfg, x, y, iters: int, seed: int) -> dict:
    """Flight recorder on vs off (DESIGN.md §11): the recorder must be
    provably cheap.  The SIMULATED critical path is the gate — tracing
    observes the clock, it must never advance it, so recorder-on and
    recorder-off runs of the same seeded model must agree to float
    identity — and the weights must stay bit-identical.  Wall time is
    reported for context, not gated (host noise dwarfs the span appends)."""
    import time as _t

    from repro.obs.trace import Recorder

    runs: dict[str, dict] = {}
    weights: dict[str, np.ndarray] = {}
    spans = 0
    for label in ("off", "on"):
        rec = Recorder() if label == "on" else None
        t0 = _t.perf_counter()
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                               make_latency("lognormal", seed=seed),
                               recorder=rec)
        weights[label] = np.asarray(runner.run(iters))
        wall = _t.perf_counter() - t0
        runs[label] = {
            "critical_path": runner.wait_stats()["critical_path"],
            "wall_s": float(wall),
        }
        if rec is not None:
            spans = len(rec.spans)
            assert not rec.open_spans()
    off_cp = runs["off"]["critical_path"]["total"]
    on_cp = runs["on"]["critical_path"]["total"]
    entry = {
        "recorder_off": runs["off"],
        "recorder_on": runs["on"],
        "spans_recorded": spans,
        "sim_critical_path_ratio": float(on_cp / off_cp) if off_cp else 1.0,
        "wall_ratio": float(runs["on"]["wall_s"] / runs["off"]["wall_s"]),
        "bit_identical": bool((weights["off"] == weights["on"]).all()),
    }
    emit("cluster_trace/overhead", runs["on"]["wall_s"] * 1e6,
         f"sim critical-path ratio {entry['sim_critical_path_ratio']:.6f}, "
         f"wall ratio {entry['wall_ratio']:.3f}, {spans} spans, "
         f"bit_identical={entry['bit_identical']}")
    return entry


def bench_sharded_masters(smoke: bool) -> dict:
    """Master-group scaling at large d (DESIGN.md §13): per-master
    critical-path coding seconds for S=1 vs S=2 over the same rounds.

    The walls are per-thread CPU seconds, so the numbers model the
    deployment (one master per machine) honestly even on a small CI box
    where the S executor threads timeslice one core.  Acceptance: the S=2
    critical path (max over the two masters of encode+decode) must be
    <= 0.75x the single master's — the d-sharding actually halves each
    master's serial coding work, minus the unsharded full-shape
    quantize/mask draws both sizes pay identically.
    """
    from repro.cluster.master_group import MasterGroup
    from repro.core.protocol import decode as _decode

    d, m, rounds = (512, 128, 2) if smoke else (4096, 512, 4)
    cfg = protocol.CPMLConfig(N=N_WORKERS, K=2, T=1, r=1)
    x, _ = synthetic.mnist_like(jax.random.PRNGKey(2), m=m, d=d)
    rng = np.random.default_rng(0)
    results = {w: rng.integers(0, cfg.p, size=(d, cfg.c)).astype(np.int32)
               for w in range(cfg.N)}
    order = np.arange(cfg.N)
    w2 = np.zeros((d, cfg.c), np.float32)
    sizes: dict[str, dict] = {}
    for size in (1, 2):
        with MasterGroup(cfg, size) as grp:
            grp.encode_dataset(cfg, jax.random.PRNGKey(0), x)
            for t in range(rounds):
                grp.encode_round_shares(
                    jax.random.fold_in(jax.random.PRNGKey(1), t), w2)
                dec = grp.make_decoder(
                    _decode.prefix_decode_plan(cfg, order), d)
                for w in order[: cfg.threshold]:
                    dec.fold(w, results[w])
                dec.finish(order)
            sizes[f"S{size}"] = grp.group_stats()
    ratio = (sizes["S2"]["critical_path_s"]
             / sizes["S1"]["critical_path_s"])
    entry = {"d": d, "m": m, "rounds": rounds, **sizes,
             "critical_path_ratio_S2_over_S1": float(ratio)}
    emit("cluster_masters/critical_path_S2",
         sizes["S2"]["critical_path_s"] * 1e6,
         f"vs S1 {sizes['S1']['critical_path_s']:.3f}s "
         f"(ratio {ratio:.3f}, d={d})")
    return entry


def bench_membership(x, y, seed: int) -> dict:
    """Elastic membership through the flight recorder (DESIGN.md §13): a
    member dies (LEAVE at a fence), the spare slot replaces it (JOIN), and
    the run must stay bit-identical to the reference on the spare-extended
    config — with the membership transitions visible as spans in the
    Perfetto-exportable trace."""
    from repro.cluster import DeadWorkerLatency, DeterministicLatency
    from repro.obs.trace import Recorder

    iters = 16
    cfg = protocol.CPMLConfig(N=N_WORKERS, K=2, T=1, r=1)
    rec = Recorder()
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                           DeadWorkerLatency(
                               DeterministicLatency(base=1.0, skew=0.1),
                               deaths={2: 3}),
                           heartbeat_timeout_s=4.0, round_timeout_s=60.0,
                           spares=1, recorder=rec)
    w = np.asarray(runner.run(iters))
    w_ref, _ = protocol.train_reference(runner.cfg, jax.random.PRNGKey(7),
                                        x, y, iters=iters,
                                        survivor_fn=runner.survivor_fn())
    stats = runner.wait_stats()["membership"]
    spans = [s for s in rec.spans if s.name == "membership_transition"]
    entry = {
        **stats,
        "transition_spans": len(spans),
        "transition_rounds": sorted({int(s.args["round"]) for s in spans}),
        "bit_identical": bool((w == np.asarray(w_ref)).all()),
    }
    emit("cluster_membership/transitions", float(len(spans)) or 1.0,
         f"epoch {stats['epoch']:.0f}, joins {stats['joins']:.0f}, "
         f"leaves {stats['leaves']:.0f}, "
         f"bit_identical={entry['bit_identical']}")
    return entry


def bench_compute(cfg, mpc_cfg, x, y) -> dict:
    """On-device wall time: one coded round vs one BGW MPC step."""
    key = jax.random.PRNGKey(0)
    st = protocol.setup(cfg, key, x, y)
    eta = 0.1
    run = protocol.round_fn(cfg, st, eta)
    import jax.numpy as jnp
    dmat, order = protocol.survivor_round(cfg, np.arange(cfg.N))
    dmat, order = jnp.asarray(dmat, jnp.int32), jnp.asarray(order, jnp.int32)
    w2 = jnp.zeros((x.shape[1], cfg.c), jnp.float32)
    coded_us = time_fn(lambda k: run(k, w2, dmat, order, None), key,
                       warmup=2, iters=5)
    mst = mpc_baseline.setup(mpc_cfg, key, x, y)
    mpc_us = time_fn(
        lambda k: mpc_baseline.step(mpc_cfg, k, mst, eta).w, key,
        warmup=2, iters=5)
    emit("cluster_compute/coded_round", coded_us, f"mpc {mpc_us:.1f}us")
    return {"coded_round_us": coded_us, "mpc_step_us": mpc_us}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_cluster.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + few rounds (CI)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    m, d, iters = (128, 32, 8) if args.smoke else (1024, 128, 40)
    cfg = protocol.CPMLConfig(N=N_WORKERS, K=2, T=1, r=1)
    mpc_cfg = mpc_baseline.MPCConfig(N=N_WORKERS, T=1, r=1)
    x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=m, d=d)

    models = {name: bench_model(name, cfg, mpc_cfg, x, y, iters, args.seed)
              for name in MODELS}
    for name in MODELS:
        models[name]["pipeline"] = bench_pipeline(name, cfg, x, y, iters,
                                                  args.seed)
    report = {
        "device": jax.default_backend(),
        "shapes": {"m": m, "d": d, "N": N_WORKERS,
                   "threshold": cfg.threshold},
        "iters": iters,
        "smoke": args.smoke,
        "models": models,
        "trace_overhead": bench_trace_overhead(cfg, x, y, iters, args.seed),
        "sharded_masters": bench_sharded_masters(args.smoke),
        "membership": bench_membership(x, y, args.seed),
        "compute_us": bench_compute(cfg, mpc_cfg, x, y),
        # the paper's Fig. 5 effect: under heavy-tailed latency the
        # first-T policy must beat waiting for everyone, strictly — and
        # the MEASURED BGW baseline must be strictly slower still.
        "acceptance": {
            **{f"{name}_T_below_all":
               bool(models[name]["coded_T"]["mean"]
                    < models[name]["wait_all"]["mean"])
               for name in ("lognormal", "bursty")},
            **{f"{name}_measured_mpc_speedup_gt_1":
               bool(models[name]["speedup_vs_mpc"] > 1.0)
               for name in ("lognormal", "bursty")},
            # DESIGN.md §9: overlapping the W-independent encode half and
            # streaming the decode must never cost critical-path time, and
            # must not change a single bit of the weights
            **{f"{name}_pipelined_not_slower": bool(
                models[name]["pipeline"]["pipelined"]["critical_path"]
                ["mean"]
                <= models[name]["pipeline"]["sequential"]["critical_path"]
                ["mean"])
               for name in ("lognormal", "bursty")},
            **{f"{name}_pipeline_bit_identical":
               bool(models[name]["pipeline"]["bit_identical_modes"])
               for name in ("lognormal", "bursty")},
        },
    }
    # DESIGN.md §11: the recorder observes the clock, never advances it —
    # the simulated critical path may not move by more than float noise
    # (≤5% is the generous bound; equality is the expectation), and tracing
    # may not change a single bit of the weights.
    report["acceptance"]["trace_overhead_ok"] = bool(
        report["trace_overhead"]["sim_critical_path_ratio"] <= 1.05)
    report["acceptance"]["trace_bit_identical"] = bool(
        report["trace_overhead"]["bit_identical"])
    # DESIGN.md §13: sharding the master over d must actually shorten each
    # master's serial coding path, and an elastic run (leave + spare join)
    # must stay bit-identical with its transitions on the trace
    report["acceptance"]["sharded_masters_critical_path"] = bool(
        report["sharded_masters"]["critical_path_ratio_S2_over_S1"] <= 0.75)
    report["acceptance"]["membership_bit_identical"] = bool(
        report["membership"]["bit_identical"])
    report["acceptance"]["membership_transitions_traced"] = bool(
        report["membership"]["transition_spans"] >= 1)
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    ok = all(report["acceptance"].values())
    print(f"wrote {out}  first_T_below_wait_all={ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
